"""Fault-tolerance tests: chaos proxy, retries, shedding, isolation.

Every fault class the serve stack claims to survive is pinned here:

* ``chaos.ChaosProxy`` itself — seeded schedules are deterministic and
  each fault kind demonstrably injures the stream the way it says.
* Client resilience — severed/truncated/bit-flipped/stalled replies end
  in a successful retry (bit-identical to the in-process sweep) or a
  typed error; never a hang past the deadline, never a wrong answer.
* Server shedding — a depth-bounded coalescer answers 503 +
  ``Retry-After`` instead of queueing unboundedly; expired deadline
  budgets are shed; draining servers refuse new work but stay probeable.
* Admission control — auth (401), rate limiting (429), and the
  DELETE/hardware + state-dir satellites.
* Isolation — one poisoned request in a fused batch fails alone (400)
  while its batchmates answer bit-identically; a straggling worker-pool
  shard is re-dispatched in-parent with a bit-identical reduction.
"""
import http.client
import json
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core import hardware, parallel, sweep
from repro.core.workload import LatticeSpec, TileConfig, WorkloadTable, \
    gemm_workload
from repro.serve import codec, errors
from repro.serve.chaos import ChaosProxy, FaultSpec, seeded_schedule
from repro.serve.client import PredictionClient
from repro.serve.server import Coalescer, PredictionServer

pytestmark = pytest.mark.serve

B200 = hardware.B200
TILES = [TileConfig(bm, bn, bk) for bm in (64, 128, 256)
         for bn in (64, 128, 256) for bk in (16, 32)]


def fresh_engine():
    return sweep.SweepEngine(use_cache=False)


def gemm_base(name="g", m=2048):
    return gemm_workload(name, m, 2048, 2048, precision="fp16")


def small_table(name="g"):
    return WorkloadTable.tile_lattice(gemm_base(name), TILES)


def same_winner(a, b):
    return (a.index == b.index and a.name == b.name and a.total == b.total
            and a.breakdown == b.breakdown
            and a.breakdown.detail == b.breakdown.detail)


@pytest.fixture(scope="module")
def served():
    server = PredictionServer(port=0).start()
    yield server
    server.shutdown()


def chaos_client(proxy, **kw):
    kw.setdefault("timeout", 5.0)
    kw.setdefault("connect_timeout", 3.0)
    kw.setdefault("backoff_base_s", 0.01)
    return PredictionClient(*proxy.address, **kw)


# ---------------------------------------------------------------------------
# the chaos proxy itself
# ---------------------------------------------------------------------------

class TestChaosProxy:
    def test_seeded_schedule_deterministic(self):
        a = seeded_schedule(7, 12)
        b = seeded_schedule(7, 12)
        assert [repr(f) for f in a] == [repr(f) for f in b]
        assert [repr(f) for f in seeded_schedule(8, 12)] \
            != [repr(f) for f in a]

    def test_seeded_schedule_pinned(self):
        # machine-independent: random.Random(seed) is specified, so this
        # exact sequence is part of the reproducibility contract
        kinds = [f.kind for f in seeded_schedule(42, 6)]
        assert kinds == [seeded_schedule(42, 6)[i].kind for i in range(6)]
        assert all(k in ("pass", "delay", "truncate", "bitflip", "sever")
                   for k in kinds)

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("explode")
        with pytest.raises(ValueError, match="flip_mask"):
            FaultSpec("bitflip", flip_mask=0)

    def test_pass_through_is_transparent(self, served):
        with ChaosProxy(*served.address) as px:
            client = chaos_client(px, max_retries=0)
            table = small_table("transparent")
            got = client.argmin(table, "b200")
            ref = sweep.argmin_table(table, B200, engine=fresh_engine())
            assert same_winner(got, ref)
            assert px.faults_injected() == 0
            client.close()

    def test_truncate_injures_exactly_after_bytes(self, served):
        with ChaosProxy(*served.address,
                        [FaultSpec("truncate", after_bytes=10)]) as px:
            conn = http.client.HTTPConnection(*px.address, timeout=5.0)
            conn.request("GET", "/v1/health")
            with pytest.raises((http.client.HTTPException, OSError)):
                resp = conn.getresponse()
                resp.read()
            conn.close()
            assert px.connection_log[0].kind == "truncate"

    def test_sever_kills_before_first_byte(self, served):
        with ChaosProxy(*served.address, [FaultSpec("sever")]) as px:
            conn = http.client.HTTPConnection(*px.address, timeout=5.0)
            with pytest.raises((http.client.HTTPException,
                                ConnectionError, OSError)):
                conn.request("GET", "/v1/health")
                conn.getresponse().read()
            conn.close()

    def test_bitflip_flips_the_named_byte(self, served):
        # fetch the same (byte-stable) reply clean and through a bitflip:
        # the bodies must differ in exactly the one injured byte
        def raw_get(host, port):
            conn = http.client.HTTPConnection(host, port, timeout=5.0)
            conn.request("GET", "/v1/hardware")   # content is stable
            resp = conn.getresponse()
            raw = resp.read()
            headers = {k.lower(): v for k, v in resp.getheaders()}
            conn.close()
            return headers, raw

        _, clean = raw_get(*served.address)
        with ChaosProxy(*served.address,
                        [FaultSpec("bitflip", flip_at=300,
                                   flip_mask=0x20)]) as px:
            _, flipped = raw_get(*px.address)
        # offset 300 of the TCP stream lands inside the body for this
        # reply (headers are shorter); bodies differ in exactly one byte
        assert len(clean) == len(flipped)
        diffs = [i for i, (a, b) in enumerate(zip(clean, flipped))
                 if a != b]
        assert len(diffs) == 1
        assert clean[diffs[0]] ^ flipped[diffs[0]] == 0x20


# ---------------------------------------------------------------------------
# client retry / breaker / deadline behavior under chaos
# ---------------------------------------------------------------------------

class TestClientRetry:
    @pytest.mark.parametrize("kind", ["sever", "truncate", "bitflip"])
    def test_destructive_fault_then_retry_bit_identical(self, served,
                                                        kind):
        spec = FaultSpec(kind, after_bytes=25, flip_at=80, flip_mask=0x10)
        table = small_table(f"retry_{kind}")
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        with ChaosProxy(*served.address, [spec]) as px:
            client = chaos_client(px)
            got = client.argmin(table, "b200")
            assert same_winner(got, ref)
            assert px.faults_injected() >= 1
            client.close()

    def test_bitflip_on_request_path_cannot_corrupt_state(self, served):
        # a flipped byte in a *reply* is retried; the request path is
        # transparent by construction (_pump_up), so the server never
        # sees injured bytes — replay the sweep cleanly to prove the
        # cache wasn't poisoned by the chaos round-trip
        table = small_table("poison_check")
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        with ChaosProxy(*served.address,
                        [FaultSpec("bitflip", flip_at=64)]) as px:
            client = chaos_client(px)
            assert same_winner(client.argmin(table, "b200"), ref)
            client.close()
        direct = PredictionClient(*served.address)
        assert same_winner(direct.argmin(table, "b200"), ref)
        direct.close()

    def test_stall_bounded_by_read_timeout_then_recovers(self, served):
        table = small_table("stall")
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        with ChaosProxy(*served.address, [FaultSpec("stall")]) as px:
            client = chaos_client(px, timeout=1.0)
            t0 = time.monotonic()
            got = client.argmin(table, "b200")
            elapsed = time.monotonic() - t0
            assert same_winner(got, ref)
            # one stalled read timeout + one clean retry, not a hang
            assert elapsed < 5.0
            client.close()

    def test_mixed_seeded_barrage_all_complete(self, served):
        # every retryable fault in a seeded barrage ends in the right
        # answer; the schedule is finite so retries eventually pass
        table = small_table("barrage")
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        schedule = seeded_schedule(1234, 8)
        with ChaosProxy(*served.address, schedule) as px:
            client = chaos_client(px, max_retries=4)
            for _ in range(6):
                assert same_winner(client.argmin(table, "b200"), ref)
            client.close()

    def test_deadline_not_reset_by_retries(self, served):
        # all-stall schedule: without a deadline each retry would pay a
        # full read timeout; the per-call deadline caps the WHOLE call
        with ChaosProxy(*served.address, [],
                        default=FaultSpec("stall")) as px:
            client = chaos_client(px, timeout=30.0, max_retries=5)
            t0 = time.monotonic()
            with pytest.raises(errors.DeadlineExceeded):
                client.argmin(small_table("dl"), "b200", deadline_s=1.0)
            elapsed = time.monotonic() - t0
            assert elapsed < 3.0       # ~1s budget, never 30s reads
            client.close()

    def test_deadline_already_spent_fails_without_io(self, served):
        client = PredictionClient(*served.address)
        with pytest.raises(errors.DeadlineExceeded):
            client.argmin(small_table("dl0"), "b200", deadline_s=0.0)
        client.close()

    def test_circuit_breaker_fails_fast_on_dead_server(self):
        # nothing listens on this socket: after threshold consecutive
        # connect failures the breaker opens and further calls refuse
        # in microseconds instead of paying another connect attempt
        import socket
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        dead_port = probe.getsockname()[1]
        probe.close()                  # port now closed -> ECONNREFUSED
        client = PredictionClient(
            "127.0.0.1", dead_port, connect_timeout=0.5, max_retries=0,
            breaker_threshold=2, breaker_cooldown_s=30.0)
        for _ in range(2):
            with pytest.raises(OSError):
                client.health()
        t0 = time.monotonic()
        with pytest.raises(errors.CircuitOpenError):
            client.health()
        assert time.monotonic() - t0 < 0.1
        client.close()

    def test_circuit_breaker_half_open_recovers(self, served):
        client = PredictionClient(
            *served.address, connect_timeout=0.5, max_retries=0,
            breaker_threshold=1, breaker_cooldown_s=0.05)
        client._breaker.failure()      # force the circuit open
        with pytest.raises(errors.CircuitOpenError):
            client.health()
        time.sleep(0.08)               # cooldown elapses -> half-open
        assert client.health()["status"] == "ok"
        # and the probe success closed the circuit for good
        assert client.health()["status"] == "ok"
        client.close()


# ---------------------------------------------------------------------------
# server shedding / admission control / satellites
# ---------------------------------------------------------------------------

class TestServerRobustness:
    def test_overload_returns_503_with_retry_after(self):
        # depth 0: every coalesced submission sheds immediately — the
        # deterministic way to exercise the load-shedding path
        with PredictionServer(port=0, max_queue_depth=0).start() as srv:
            body = codec.encode_request("argmin", small_table("ov"),
                                        hw="b200")
            conn = http.client.HTTPConnection(*srv.address, timeout=5.0)
            conn.request("POST", "/v1/argmin", body=body, headers={
                "Content-Type": "application/x-repro-wire"})
            resp = conn.getresponse()
            data = resp.read()
            assert resp.status == 503
            assert float(resp.getheader("Retry-After")) > 0
            with pytest.raises(codec.RemoteError, match="depth bound"):
                codec.raise_if_error(data)
            conn.close()
            # typed client-side too, after its retries are exhausted
            client = PredictionClient(*srv.address, max_retries=1,
                                      backoff_base_s=0.01)
            with pytest.raises(errors.ServerOverloaded):
                client.argmin(small_table("ov"), "b200")
            assert srv.coalescer.stats["shed_overload"] >= 2
            # opting out of coalescing bypasses the queue bound
            t = small_table("ov_direct")
            assert same_winner(
                client.argmin(t, "b200", coalesce=False),
                sweep.argmin_table(t, B200, engine=fresh_engine()))
            client.close()

    def test_expired_deadline_header_is_shed_with_503(self, served):
        conn = http.client.HTTPConnection(*served.address, timeout=5.0)
        conn.request("POST", "/v1/argmin", body=b"irrelevant", headers={
            "Content-Length": "10", errors.DEADLINE_HEADER: "-0.5"})
        resp = conn.getresponse()
        assert resp.status == 503
        resp.read()
        conn.close()

    def test_malformed_deadline_header_is_400(self, served):
        conn = http.client.HTTPConnection(*served.address, timeout=5.0)
        conn.request("POST", "/v1/argmin", body=b"x", headers={
            "Content-Length": "1", errors.DEADLINE_HEADER: "soon"})
        resp = conn.getresponse()
        assert resp.status == 400
        resp.read()
        conn.close()

    def test_queued_deadline_expiry_sheds_server_side(self):
        with PredictionServer(port=0, coalesce_window_s=0.3).start() \
                as srv:
            # the window parks the request long enough for a tiny budget
            # to expire while queued; the coalescer sheds it un-evaluated
            client = PredictionClient(*srv.address, max_retries=0)
            with pytest.raises((errors.DeadlineExceeded,
                                errors.ServerOverloaded)):
                client.argmin(small_table("qdl"), "b200",
                              deadline_s=0.05)
            deadline = time.monotonic() + 5.0
            while srv.coalescer.stats["shed_deadline"] == 0 \
                    and time.monotonic() < deadline:
                time.sleep(0.01)
            assert srv.coalescer.stats["shed_deadline"] >= 1
            client.close()

    def test_auth_token_gates_mutating_endpoints(self):
        with PredictionServer(port=0, auth_token="hunter2").start() \
                as srv:
            table = small_table("auth")
            anon = PredictionClient(*srv.address)
            # reads and sweeps stay open
            assert anon.health()["status"] == "ok"
            anon.argmin(table, "b200")
            # mutations without the token are 401
            with pytest.raises(errors.Unauthorized):
                anon.clear_cache()
            with pytest.raises(errors.Unauthorized):
                anon.hardware_delete("b200")
            anon.close()
            wrong = PredictionClient(*srv.address, auth_token="guess")
            with pytest.raises(errors.Unauthorized):
                wrong.clear_cache()
            wrong.close()
            good = PredictionClient(*srv.address, auth_token="hunter2")
            assert good.clear_cache() == {"cleared": True}
            good.close()
            # Authorization: Bearer spelling works too
            conn = http.client.HTTPConnection(*srv.address, timeout=5.0)
            conn.request("POST", "/v1/clear_cache", body=b"", headers={
                "Content-Length": "0",
                "Authorization": "Bearer hunter2"})
            assert conn.getresponse().status == 200
            conn.close()

    def test_rate_limit_returns_429_with_retry_after(self):
        with PredictionServer(port=0, mutate_rps=1.0,
                              mutate_burst=2).start() as srv:
            conn = http.client.HTTPConnection(*srv.address, timeout=5.0)
            statuses = []
            for _ in range(3):
                conn.request("POST", "/v1/clear_cache", body=b"",
                             headers={"Content-Length": "0"})
                resp = conn.getresponse()
                resp.read()
                statuses.append(resp.status)
                if resp.will_close:
                    conn.close()
                    conn = http.client.HTTPConnection(*srv.address,
                                                      timeout=5.0)
                if resp.status == 429:
                    assert float(resp.getheader("Retry-After")) > 0
            conn.close()
            assert statuses == [200, 200, 429]
            # the client retries 429s honoring Retry-After and succeeds
            client = PredictionClient(*srv.address, max_retries=3)
            assert client.clear_cache() == {"cleared": True}
            client.close()

    def test_delete_hardware_tombstones_and_404s(self):
        import dataclasses
        with PredictionServer(port=0).start() as srv:
            client = PredictionClient(*srv.address)
            entry = dataclasses.replace(hardware.get("b200"),
                                        name="fault_test_hw")
            client.hardware_register(entry)
            assert "fault_test_hw" in client.health()["hardware"]
            assert client.hardware_delete("fault_test_hw") \
                == {"deleted": "fault_test_hw"}
            assert "fault_test_hw" not in client.health()["hardware"]
            # second DELETE: 404 (documented retry semantics: a client
            # that re-sends after a lost reply treats this as success)
            with pytest.raises(codec.RemoteError, match="fault_test_hw"):
                client.hardware_delete("fault_test_hw")
            # sweeps against the tombstoned name are clean 400s
            with pytest.raises(codec.RemoteError, match="fault_test_hw"):
                client.argmin(small_table("del"), "fault_test_hw")
            client.close()

    def test_delete_file_backed_entry_masks_until_reregistered(self):
        with PredictionServer(port=0).start() as srv:
            client = PredictionClient(*srv.address)
            entry = client.hardware_get("mi300a")
            client.hardware_delete("mi300a")
            try:
                assert "mi300a" not in client.health()["hardware"]
                with pytest.raises(codec.RemoteError):
                    client.hardware_get("mi300a")
            finally:
                # restore for other tests (module registry is global)
                client.hardware_register(entry, overwrite=True)
            assert "mi300a" in client.health()["hardware"]
            client.close()

    def test_state_dir_snapshot_and_reload(self, tmp_path):
        from repro.core.microbench import MeasuredSuite
        state = str(tmp_path / "state")
        hw = hardware.get("b200")
        ws = [gemm_workload(f"cal{i}", 512 * (i + 1), 512, 512)
              for i in range(6)]
        with PredictionServer(port=0, state_dir=state).start() as srv:
            client = PredictionClient(*srv.address)
            meas = [srv.engine.predict(w, hw).total * 1.25 for w in ws]
            cal, _ = client.calibrate(
                MeasuredSuite("faults", ws, [float(m) for m in meas]),
                "b200", register_as="persisted")
            client.close()
        # shutdown snapshotted; a fresh instance reloads the fit exactly
        blob = json.loads(
            (tmp_path / "state" / "calibrations.json").read_text())
        assert "persisted" in blob["calibrations"]
        srv2 = PredictionServer(port=0, state_dir=state)
        try:
            assert srv2.calibrations["persisted"].cal.to_dict() \
                == cal.to_dict()
        finally:
            srv2.shutdown()

    def test_corrupt_state_file_is_tolerated(self, tmp_path, capsys):
        state = tmp_path / "state"
        state.mkdir()
        (state / "calibrations.json").write_text("{not json")
        srv = PredictionServer(port=0, state_dir=str(state))
        try:
            assert srv.calibrations == {}
        finally:
            srv.shutdown()

    def test_draining_server_sheds_posts_but_answers_gets(self):
        srv = PredictionServer(port=0).start()
        try:
            client = PredictionClient(*srv.address, max_retries=0)
            srv._draining = True       # the flag alone drives shedding
            h = client.health()
            assert h["draining"] is True and h["status"] == "draining"
            with pytest.raises(errors.ServerOverloaded,
                               match="draining"):
                client.argmin(small_table("drain"), "b200")
            with pytest.raises(errors.ServerOverloaded):
                client.hardware_delete("b200")
            srv._draining = False
            client.close()
        finally:
            srv.shutdown()

    def test_sigterm_drains_subprocess_and_snapshots_state(self,
                                                           tmp_path):
        from repro.core.microbench import MeasuredSuite
        from repro.serve.subproc import start_server_subprocess
        state = str(tmp_path / "state")
        proc, host, port = start_server_subprocess(
            ["--state-dir", state])
        try:
            client = PredictionClient(host, port, timeout=30.0)
            hw = hardware.get("b200")
            ws = [gemm_workload(f"d{i}", 512 * (i + 1), 512, 512)
                  for i in range(5)]
            eng = fresh_engine()
            meas = [eng.predict(w, hw).total * 1.4 for w in ws]
            client.calibrate(
                MeasuredSuite("drain", ws, [float(m) for m in meas]),
                "b200", register_as="drained_cal")
            client.close()
            proc.send_signal(signal.SIGTERM)
            assert proc.wait(timeout=20) == 0
            blob = json.loads(
                (tmp_path / "state" / "calibrations.json").read_text())
            assert "drained_cal" in blob["calibrations"]
        finally:
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=10)


# ---------------------------------------------------------------------------
# coalescer failure isolation
# ---------------------------------------------------------------------------

class PoisonEngine(sweep.SweepEngine):
    """Engine that refuses any table containing an fp64 row — the
    deterministic stand-in for a request that fails mid-batch.  The
    sentinel rides the precision column because it survives both the
    wire round-trip and ``WorkloadTable.concat`` (row *names* do not:
    fusing tables with different shared names drops them)."""

    def predict_table(self, table, hw, **kw):
        if "fp64" in {table.precision_vocab[c]
                      for c in table.precision_codes}:
            raise ValueError("poisoned row (fp64 sentinel)")
        return super().predict_table(table, hw, **kw)


def poison_table(name="POISON"):
    return WorkloadTable.tile_lattice(
        gemm_workload(name, 2048, 2048, 2048, precision="fp64"), TILES)


class TestCoalescerIsolation:
    def test_poisoned_request_fails_alone_direct(self):
        # window forces the healthy + poisoned requests into one batch
        engine = PoisonEngine()
        co = Coalescer(engine, window_s=0.15)
        try:
            healthy = [small_table(f"ok{i}") for i in range(3)]
            poisoned = poison_table()
            results = {}
            failures = {}

            def run(key, table):
                try:
                    results[key] = co.submit("argmin", table, B200, None)
                except BaseException as e:   # noqa: BLE001
                    failures[key] = e

            threads = [threading.Thread(target=run, args=(i, t))
                       for i, t in enumerate(healthy + [poisoned])]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert not any(t.is_alive() for t in threads)
            # only the poisoned request failed, and with its own error
            assert set(failures) == {3}
            assert "poisoned" in str(failures[3])
            assert co.stats["isolated_failures"] >= 1
            # the healthy batchmates answered bit-identically to solo
            for i, table in enumerate(healthy):
                ref = sweep.argmin_table(table, B200,
                                         engine=fresh_engine())
                assert same_winner(results[i][0], ref)
        finally:
            co.close()

    def test_poisoned_request_fails_alone_over_http(self):
        srv = PredictionServer(port=0, engine=PoisonEngine(),
                               coalesce_window_s=0.15).start()
        try:
            client = PredictionClient(*srv.address, max_retries=0)
            healthy = [small_table(f"h{i}") for i in range(3)]
            poisoned = poison_table()
            results = {}
            failures = {}

            def run(key, table):
                try:
                    results[key] = client.argmin(table, "b200")
                except BaseException as e:   # noqa: BLE001
                    failures[key] = e

            threads = [threading.Thread(target=run, args=(i, t))
                       for i, t in enumerate(healthy + [poisoned])]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert not any(t.is_alive() for t in threads)
            assert set(failures) == {3}
            assert isinstance(failures[3], codec.RemoteError)  # a 400
            assert "poisoned" in str(failures[3])
            for i, table in enumerate(healthy):
                ref = sweep.argmin_table(table, B200,
                                         engine=fresh_engine())
                assert same_winner(results[i], ref)
            client.close()
        finally:
            srv.shutdown()

    def test_all_poisoned_batch_every_request_gets_the_error(self):
        co = Coalescer(PoisonEngine(), window_s=0.1)
        try:
            failures = []

            def run(table):
                try:
                    co.submit("argmin", table, B200, None)
                except BaseException as e:   # noqa: BLE001
                    failures.append(e)

            threads = [threading.Thread(
                target=run, args=(poison_table(f"POISON{i}"),))
                for i in range(2)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert len(failures) == 2
            assert all("poisoned" in str(e) for e in failures)
        finally:
            co.close()


# ---------------------------------------------------------------------------
# worker-pool straggler re-dispatch
# ---------------------------------------------------------------------------

@pytest.fixture
def straggler_spec():
    tiles = [TileConfig(bm, bn, bk) for bm in (64, 128, 256)
             for bn in (64, 128, 256) for bk in (16, 32)]
    return LatticeSpec.tile_lattice(gemm_base("straggle", 4096), tiles)


@pytest.fixture
def hook_cleanup():
    yield
    parallel._SHARD_FAULT_HOOK = None


class TestStragglerRedispatch:
    def test_hung_shard_redispatched_bit_identical(self, straggler_spec,
                                                   hook_cleanup):
        ref = sweep.argmin_stream(straggler_spec, B200, chunk_size=4)
        hung = []

        def hang_once(lo, hi):
            if lo == 0 and not hung:
                hung.append(True)
                time.sleep(30.0)     # far past the straggler timeout

        parallel._SHARD_FAULT_HOOK = hang_once
        pool = parallel.WorkerPool(2, use_threads=True,
                                   straggler_timeout_s=0.5)
        try:
            t0 = time.monotonic()
            got = sweep.argmin_stream(straggler_spec, B200, chunk_size=4,
                                      jobs=2, pool=pool)
            elapsed = time.monotonic() - t0
        finally:
            parallel._SHARD_FAULT_HOOK = None
            pool.close()
        assert hung                     # the fault actually fired
        assert same_winner(got, ref)    # re-dispatch is bit-identical
        assert elapsed < 10.0           # timeout + in-parent rerun, not 30s

    def test_genuine_worker_error_propagates_unchanged(self,
                                                       straggler_spec,
                                                       hook_cleanup):
        def explode(lo, hi):
            raise ValueError("genuinely broken shard")

        parallel._SHARD_FAULT_HOOK = explode
        pool = parallel.WorkerPool(2, use_threads=True,
                                   straggler_timeout_s=5.0)
        try:
            with pytest.raises(ValueError, match="genuinely broken"):
                sweep.argmin_stream(straggler_spec, B200, chunk_size=4,
                                    jobs=2, pool=pool)
        finally:
            parallel._SHARD_FAULT_HOOK = None
            pool.close()

    def test_both_attempts_dying_raises_straggler_error(
            self, straggler_spec, hook_cleanup):
        seen = set()

        def die_twice(lo, hi):
            if lo == 0:
                if (lo, hi) not in seen:
                    seen.add((lo, hi))
                    time.sleep(30.0)          # first attempt: hang
                raise RuntimeError("re-dispatch died too")

        parallel._SHARD_FAULT_HOOK = die_twice
        pool = parallel.WorkerPool(2, use_threads=True,
                                   straggler_timeout_s=0.5)
        try:
            with pytest.raises(parallel.StragglerError,
                               match="failed twice"):
                sweep.argmin_stream(straggler_spec, B200, chunk_size=4,
                                    jobs=2, pool=pool)
        finally:
            parallel._SHARD_FAULT_HOOK = None
            pool.close()

    def test_no_timeout_means_no_behavior_change(self, straggler_spec):
        # default (None) keeps the old semantics: wait forever, no
        # re-dispatch machinery in the result path
        ref = sweep.argmin_stream(straggler_spec, B200, chunk_size=4)
        pool = parallel.WorkerPool(2, use_threads=True)
        try:
            got = sweep.argmin_stream(straggler_spec, B200, chunk_size=4,
                                      jobs=2, pool=pool)
        finally:
            pool.close()
        assert same_winner(got, ref)

    def test_pool_recover_swaps_broken_executor(self):
        pool = parallel.WorkerPool(2, use_threads=True)
        try:
            old = pool.executor
            pool.recover(broken=old)
            assert pool.executor is not old
            # recover against a non-current executor is a no-op
            current = pool.executor
            pool.recover(broken=old)
            assert pool.executor is current
        finally:
            pool.close()


# ---------------------------------------------------------------------------
# chaos against the binary transport
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_bin():
    server = PredictionServer(port=0, binary_port=0).start()
    yield server
    server.shutdown()


def bin_chaos_client(server, proxy, **kw):
    """Client whose binary socket rides the chaos proxy; pinned to the
    binary transport so a destructive fault retries on the binary path
    instead of auto-downgrading to (unproxied) HTTP."""
    kw.setdefault("timeout", 5.0)
    kw.setdefault("connect_timeout", 3.0)
    kw.setdefault("backoff_base_s", 0.01)
    return PredictionClient(*server.address, transport="binary",
                            binary_port=proxy.address[1], **kw)


class TestBinaryChaos:
    """Every FaultSpec kind against the framed socket: each must end in
    a typed error or a bit-identical retry — never a hang past the
    deadline, never a wrong answer (the satellite the binary transport
    must clear before it is allowed to exist)."""

    @pytest.mark.parametrize("spec", [
        FaultSpec("sever"),                       # dies before any reply
        FaultSpec("truncate", after_bytes=30),    # cut mid frame header
        FaultSpec("bitflip", flip_at=16),         # frame header corrupted
        FaultSpec("bitflip", flip_at=80),         # payload corrupted (CRC)
    ], ids=("sever", "truncate", "bitflip-header", "bitflip-payload"))
    def test_destructive_reply_fault_retries_bit_identical(
            self, served_bin, spec):
        table = small_table(f"bin-{spec.kind}-{spec.flip_at}")
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        with ChaosProxy(*served_bin.binary_address, [spec]) as px:
            client = bin_chaos_client(served_bin, px)
            got = client.argmin(table, "b200")
            assert same_winner(got, ref)
            assert px.faults_injected() >= 1
            client.close()

    def test_stall_bounded_by_read_timeout_then_recovers(self,
                                                         served_bin):
        table = small_table("bin-stall")
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        with ChaosProxy(*served_bin.binary_address,
                        [FaultSpec("stall")]) as px:
            client = bin_chaos_client(served_bin, px, timeout=1.0)
            t0 = time.monotonic()
            got = client.argmin(table, "b200")
            elapsed = time.monotonic() - t0
            assert same_winner(got, ref)     # retry conn passed through
            assert elapsed < 5.0             # one read timeout, not a hang
            assert px.faults_injected() >= 1
            client.close()

    def test_every_conn_stalling_deadline_wins_no_hang(self, served_bin):
        with ChaosProxy(*served_bin.binary_address, [],
                        default=FaultSpec("stall")) as px:
            client = bin_chaos_client(served_bin, px, timeout=30.0,
                                      max_retries=10)
            t0 = time.monotonic()
            with pytest.raises(errors.DeadlineExceeded):
                client.argmin(small_table("bin-dl"), "b200",
                              deadline_s=1.5)
            assert time.monotonic() - t0 < 4.0
            client.close()

    def test_seeded_barrage_pipelined_all_bit_identical(self, served_bin):
        # a reproducible mixed fault barrage under a pipelined burst:
        # severed mid-stream replies re-send only what is outstanding,
        # corrupt frames are caught by header strictness or payload CRC,
        # and every table still answers bit-identically
        tables = [WorkloadTable.tile_lattice(
            gemm_base(f"bz{j}", 2048 + 128 * j), TILES)
            for j in range(6)]
        eng = fresh_engine()
        refs = [sweep.argmin_table(t, B200, engine=eng) for t in tables]
        with ChaosProxy(*served_bin.binary_address,
                        seeded_schedule(11, 8)) as px:
            client = bin_chaos_client(served_bin, px, max_retries=10)
            wins = client.argmin_many(tables, "b200")
            assert len(wins) == 6
            assert all(same_winner(a, b) for a, b in zip(wins, refs))
            client.close()


# ---------------------------------------------------------------------------
# process-level chaos: SIGKILL the server mid-stream
# ---------------------------------------------------------------------------

class TestProcessKill:
    def test_sigkill_surfaces_retryable_error_and_opens_breaker(self):
        from repro.obs import metrics
        from repro.serve import subproc
        from repro.serve.chaos import kill_server_process

        breaker_opens = metrics.counter("repro_client_breaker_open_total")
        proc, host, port = subproc.start_server_subprocess()
        client = PredictionClient(
            host, port, timeout=5.0, connect_timeout=1.0, max_retries=1,
            backoff_base_s=0.01, breaker_threshold=2,
            breaker_cooldown_s=60.0)
        try:
            client.health()              # establish the keep-alive socket
            opens_before = breaker_opens.value

            status = kill_server_process(proc)
            assert status == -signal.SIGKILL

            # the established connection died without a FIN handshake
            # completing the protocol: the next call rides the dead
            # keep-alive socket, gets RST/refused on reconnect, and
            # surfaces as a TYPED retryable transport fault (or, if the
            # connect failures already tripped the breaker mid-retry,
            # as the breaker's typed fail-fast) — never a hang
            t0 = time.monotonic()
            with pytest.raises((ConnectionError, OSError,
                                errors.DeadlineExceeded,
                                errors.CircuitOpenError)):
                client.argmin(small_table("killed"), "b200",
                              deadline_s=10.0)
            assert time.monotonic() - t0 < 10.0

            # consecutive connect failures open the circuit: fail fast
            for _ in range(4):
                try:
                    client.health()
                except errors.CircuitOpenError:
                    break
                except (ConnectionError, OSError):
                    continue
            with pytest.raises(errors.CircuitOpenError):
                client.health()
            # the closed->open transition was counted exactly as such
            assert breaker_opens.value >= opens_before + 1
        finally:
            client.close()
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=10)

    def test_kill_is_idempotent_on_dead_process(self):
        from repro.serve import subproc
        from repro.serve.chaos import kill_server_process

        proc, host, port = subproc.start_server_subprocess()
        assert kill_server_process(proc) == -signal.SIGKILL
        # killing an already-reaped process just reaps it again
        assert kill_server_process(proc) == -signal.SIGKILL

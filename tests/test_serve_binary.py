"""Binary transport tests: framing fuzz, event-loop server, pipelined
request ids, cross-request dedup, adaptive fused-row budget.

The contract under test (serve/README.md "Binary framing (v1)"):

* the 24-byte header is strict — any malformed field raises
  ``WireFormatError`` and poisons the stream (both sides close rather
  than resynchronize), mirrored here with an every-bit-flip fuzz sweep
  over the header like the codec's envelope fuzz;
* request ids demux pipelined replies — a duplicate in-flight id closes
  the connection, and a reply can never land on the wrong id;
* every answer served over the binary port is bit-identical to the HTTP
  and in-process routes (same coalescer, same engine, same codec);
* concurrent same-content tables evaluate once (dedup keyed on
  ``content_token`` within a hardware/route/calibration group) while
  each request keeps its OWN row names;
* the fused-batch budget is in estimated cost units (scalar-fallback
  rows ~50x vectorized), observable in stats and tunable per server and
  per request (hints clamp server-side).
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.core import hardware, sweep
from repro.core.workload import TileConfig, Workload, WorkloadTable, \
    gemm_workload
from repro.serve import codec, errors, framing
from repro.serve.client import PredictionClient
from repro.serve.server import (MAX_FUSED_ROWS, SCALAR_ROW_COST, Coalescer,
                                PredictionServer)

pytestmark = pytest.mark.serve

B200 = hardware.B200
TILES = [TileConfig(bm, bn, bk) for bm in (64, 128, 256)
         for bn in (64, 128) for bk in (16, 32)]


def fresh_engine():
    return sweep.SweepEngine(use_cache=False)


def gemm_base(name="g", m=2048):
    return gemm_workload(name, m, 2048, 2048, precision="fp16")


def small_table(name="g", m=2048):
    return WorkloadTable.tile_lattice(gemm_base(name, m), TILES)


def scalar_table(name="s", n=4, scale=1.0):
    """Rows with explicit hit rates: the scalar-fallback path, costed at
    ``SCALAR_ROW_COST`` units each by the adaptive budget.  ``scale``
    varies the content so distinct tables don't dedup-collapse."""
    return WorkloadTable.from_workloads(
        [Workload(name=f"{name}{i}", wclass="memory",
                  flops=1e9 * (i + 1) * scale, bytes=1e9,
                  hit_rates={"h_l2": 0.6, "h_l1": 0.3})
         for i in range(n)])


def same_winner(a, b):
    return (a.index == b.index and a.name == b.name and a.total == b.total
            and a.breakdown == b.breakdown
            and a.breakdown.detail == b.breakdown.detail)


@pytest.fixture(scope="module")
def served_bin():
    server = PredictionServer(port=0, binary_port=0).start()
    yield server
    server.shutdown()


def bin_client(server, **kw):
    """Client pinned to the server's binary port (no probe)."""
    kw.setdefault("backoff_base_s", 0.01)
    return PredictionClient(*server.address,
                            binary_port=server.binary_address[1], **kw)


# ---------------------------------------------------------------------------
# framing: pack/parse and the fuzz sweep
# ---------------------------------------------------------------------------

class TestFraming:
    def test_roundtrip(self):
        payload = b"x" * 37
        raw = framing.pack_frame(framing.OP_SWEEP, 71, payload,
                                 deadline_s=2.5)
        p = framing.FrameParser()
        p.feed(raw)
        frames = list(p.frames())
        assert len(frames) == 1
        f = frames[0]
        assert (f.op, f.req_id, f.payload) == (framing.OP_SWEEP, 71,
                                               payload)
        assert f.deadline_s == pytest.approx(2.5)
        assert f.flags == 0
        assert len(p) == 0

    def test_byte_at_a_time_feed(self):
        raw = framing.pack_frame(framing.OP_HEALTH, 9, b"abc")
        p = framing.FrameParser()
        for i, b in enumerate(raw):
            p.feed(bytes([b]))
            got = list(p.frames())
            if i < len(raw) - 1:
                assert got == []          # truncated frame: not an error
            else:
                assert got[0].payload == b"abc"

    def test_pipelined_frames_in_order(self):
        frames = [framing.pack_frame(framing.OP_SWEEP, i,
                                     bytes([i]) * (10 + i))
                  for i in range(5)]
        blob = b"".join(frames)
        p = framing.FrameParser()
        out = []
        for lo in range(0, len(blob), 7):     # deliberately odd chunks
            p.feed(blob[lo:lo + 7])
            out.extend(p.frames())
        assert [f.req_id for f in out] == [0, 1, 2, 3, 4]
        assert all(f.payload == bytes([i]) * (10 + i)
                   for i, f in enumerate(out))

    def test_truncated_length_waits_never_errors(self):
        raw = framing.pack_frame(framing.OP_SWEEP, 1, b"q" * 100)
        p = framing.FrameParser()
        p.feed(raw[:-1])                      # one payload byte short
        assert list(p.frames()) == []
        p.feed(raw[-1:])
        assert list(p.frames())[0].payload == b"q" * 100

    def test_oversized_length_rejected_and_poisons(self):
        hdr = framing.HEADER.pack(framing.BIN_MAGIC, framing.OP_SWEEP, 0,
                                  0, framing.MAX_FRAME_BYTES + 1, 1, 0.0)
        p = framing.FrameParser()
        p.feed(hdr)
        with pytest.raises(codec.WireFormatError, match="exceeds"):
            list(p.frames())
        # poisoned: the stream offset is untrustworthy from here on
        with pytest.raises(codec.WireFormatError, match="close"):
            p.feed(b"more")
        with pytest.raises(codec.WireFormatError, match="close"):
            list(p.frames())

    def test_bad_magic_rejected(self):
        raw = bytearray(framing.pack_frame(framing.OP_HEALTH, 1, b""))
        raw[:4] = b"HTTP"
        p = framing.FrameParser()
        p.feed(bytes(raw))
        with pytest.raises(codec.WireFormatError, match="magic"):
            list(p.frames())

    def test_nonzero_reserved_rejected(self):
        hdr = framing.HEADER.pack(framing.BIN_MAGIC, framing.OP_HEALTH, 0,
                                  7, 0, 1, 0.0)
        p = framing.FrameParser()
        p.feed(hdr)
        with pytest.raises(codec.WireFormatError, match="reserved"):
            list(p.frames())

    def test_unknown_op_and_flags_rejected(self):
        for op, flags in ((200, 0), (framing.OP_SWEEP, 0x80)):
            hdr = framing.HEADER.pack(framing.BIN_MAGIC, op, flags, 0, 0,
                                      1, 0.0)
            p = framing.FrameParser()
            p.feed(hdr)
            with pytest.raises(codec.WireFormatError):
                list(p.frames())

    def test_invalid_deadline_rejected(self):
        for bad in (float("nan"), float("inf"), -1.0):
            hdr = framing.HEADER.pack(framing.BIN_MAGIC, framing.OP_SWEEP,
                                      0, 0, 0, 1, bad)
            p = framing.FrameParser()
            p.feed(hdr)
            with pytest.raises(codec.WireFormatError, match="deadline"):
                list(p.frames())

    def test_pack_frame_validates(self):
        with pytest.raises(ValueError, match="unknown op"):
            framing.pack_frame(99, 1, b"")
        with pytest.raises(ValueError, match="u64"):
            framing.pack_frame(framing.OP_SWEEP, -1, b"")
        with pytest.raises(ValueError, match="u64"):
            framing.pack_frame(framing.OP_SWEEP, 1 << 64, b"")

    def test_every_header_bit_flip_is_caught_or_visible(self):
        """The framing mirror of the codec's envelope fuzz: flip every
        bit of every header byte.  Each flip must either raise
        ``WireFormatError``, leave the parser waiting for more bytes
        (a length now pointing past the buffer), or surface as a frame
        that visibly differs from the original — NEVER parse back to
        the original frame, and never escape as a non-wire error."""
        payload = b"p" * 40
        raw = framing.pack_frame(framing.OP_SWEEP, 0x1234, payload,
                                 deadline_s=1.5)
        ref = framing.Frame(framing.OP_SWEEP, 0, 0x1234, 1.5, payload)
        outcomes = {"error": 0, "waiting": 0, "differs": 0}
        for off in range(framing.HEADER.size):
            for bit in range(8):
                buf = bytearray(raw)
                buf[off] ^= 1 << bit
                p = framing.FrameParser()
                p.feed(bytes(buf))
                try:
                    got = list(p.frames())
                except codec.WireFormatError:
                    outcomes["error"] += 1
                    continue
                if not got:
                    outcomes["waiting"] += 1
                    continue
                f = got[0]
                assert (f.op, f.flags, f.req_id, f.deadline_s,
                        f.payload) != (ref.op, ref.flags, ref.req_id,
                                       ref.deadline_s, ref.payload), \
                    f"flip at byte {off} bit {bit} was invisible"
                outcomes["differs"] += 1
        # sanity on the sweep's coverage: all three outcomes occur
        # (magic flips error out, high length-bits leave it waiting,
        # req-id flips produce visibly different frames)
        assert outcomes["error"] >= 32          # 4 magic bytes at least
        assert outcomes["waiting"] >= 1
        assert outcomes["differs"] >= 64        # 8 req-id bytes at least


# ---------------------------------------------------------------------------
# the served binary transport
# ---------------------------------------------------------------------------

class TestBinaryTransport:
    def test_bit_identical_across_all_routes(self, served_bin):
        table = small_table("routes")
        eng = fresh_engine()
        c = bin_client(served_bin)
        http_c = PredictionClient(*served_bin.address, transport="http")
        try:
            ref = sweep.argmin_table(table, B200, engine=eng)
            assert same_winner(c.argmin(table, "b200"), ref)
            assert same_winner(http_c.argmin(table, "b200"), ref)
            ref_k = sweep.topk_table(table, B200, 5, engine=eng)
            got_k = c.topk(table, "b200", 5)
            assert len(got_k) == 5
            assert all(same_winner(a, b) for a, b in zip(got_k, ref_k))
            ref_p = sweep.pareto_table(table, B200, engine=eng)
            got_p = c.pareto(table, "b200")
            assert len(got_p) == len(ref_p)
            assert all(same_winner(a, b) for a, b in zip(got_p, ref_p))
            tot = c.predict_totals(table, "b200")
            ref_t = eng.predict_table(table, B200).totals
            assert np.array_equal(tot, np.asarray(ref_t))
        finally:
            c.close()
            http_c.close()

    def test_auto_negotiation_upgrades(self, served_bin):
        c = PredictionClient(*served_bin.address)   # transport="auto"
        try:
            table = small_table("nego")
            ref = sweep.argmin_table(table, B200, engine=fresh_engine())
            assert same_winner(c.argmin(table, "b200"), ref)
            assert c._bin_target == served_bin.binary_address
            before = served_bin.binary.stats["requests"]
            assert same_winner(c.argmin(table, "b200"), ref)
            assert served_bin.binary.stats["requests"] > before
        finally:
            c.close()

    def test_http_only_server_stays_http(self):
        with PredictionServer(port=0).start() as srv:
            c = PredictionClient(*srv.address)
            table = small_table("httponly")
            ref = sweep.argmin_table(table, B200, engine=fresh_engine())
            assert same_winner(c.argmin(table, "b200"), ref)
            assert c._bin_target is None
            assert c.health()["binary_port"] is None
            c.close()
            with pytest.raises(RuntimeError, match="no binary port"):
                forced = PredictionClient(*srv.address,
                                          transport="binary")
                try:
                    forced.argmin(table, "b200")
                finally:
                    forced.close()

    def test_stale_binary_port_falls_back_to_http(self):
        with PredictionServer(port=0).start() as srv:
            # nothing listens on this port: connect refuses instantly
            probe = socket.socket()
            probe.bind(("127.0.0.1", 0))
            dead = probe.getsockname()[1]
            probe.close()
            c = PredictionClient(*srv.address, binary_port=dead,
                                 max_retries=1, backoff_base_s=0.01,
                                 breaker_threshold=0)
            table = small_table("stale")
            ref = sweep.argmin_table(table, B200, engine=fresh_engine())
            assert same_winner(c.argmin(table, "b200"), ref)
            assert c._bin_disabled          # downgraded for good
            assert same_winner(c.argmin(table, "b200"), ref)
            c.close()

    def test_pipelined_ids_demux_any_completion_order(self, served_bin):
        # mixed sizes so fused evaluations complete out of submission
        # order; every reply must still land on its own request id
        tables = [small_table(f"p{j}", 1024 + 256 * (j % 7))
                  for j in range(16)]
        eng = fresh_engine()
        refs = [sweep.argmin_table(t, B200, engine=eng) for t in tables]
        c = bin_client(served_bin)
        try:
            wins = c.argmin_many(tables, "b200")
            assert len(wins) == 16
            assert all(same_winner(a, b) for a, b in zip(wins, refs))
        finally:
            c.close()

    def test_health_and_stats_one_schema_both_transports(self,
                                                         served_bin):
        http_c = PredictionClient(*served_bin.address, transport="http")
        b_c = bin_client(served_bin, transport="binary")
        try:
            via_http = http_c.cache_stats()
            via_bin = b_c.cache_stats()
            assert set(via_http) == set(via_bin)
            for key in ("coalescer_deduped_requests",
                        "coalescer_dedup_rows_saved",
                        "coalescer_shed_overload",
                        "coalescer_shed_deadline",
                        "coalescer_isolated_failures",
                        "coalescer_max_fused_rows",
                        "binary_requests", "binary_frames_in",
                        "binary_frames_out", "binary_connections",
                        "binary_connections_open",
                        "binary_protocol_errors"):
                assert key in via_http, key
            assert b_c.health()["binary_port"] \
                == served_bin.binary_address[1]
        finally:
            http_c.close()
            b_c.close()

    def test_http_only_stats_zero_fill_same_schema(self, served_bin):
        with PredictionServer(port=0).start() as srv:
            c = PredictionClient(*srv.address)
            plain = c.cache_stats()
            c.close()
        c2 = PredictionClient(*served_bin.address, transport="http")
        with_bin = c2.cache_stats()
        c2.close()
        assert set(plain) == set(with_bin)
        assert plain["binary_requests"] == 0
        assert plain["binary_connections_open"] == 0

    def test_duplicate_inflight_id_closes_connection(self):
        # a window keeps the first request parked long enough for the
        # duplicate id to arrive while it is genuinely in flight
        with PredictionServer(port=0, binary_port=0,
                              coalesce_window_s=0.3).start() as srv:
            body = codec.encode_request("argmin", small_table("dup"),
                                        hw="b200")
            s = socket.create_connection(srv.binary_address, timeout=10)
            try:
                s.sendall(framing.pack_frame(framing.OP_SWEEP, 5, body))
                s.sendall(framing.pack_frame(framing.OP_SWEEP, 5, body))
                deadline = time.monotonic() + 10
                closed = False
                while time.monotonic() < deadline:
                    data = s.recv(65536)
                    if not data:
                        closed = True
                        break
                assert closed, "duplicate id must close the connection"
            finally:
                s.close()
            assert srv.binary.stats["protocol_errors"] >= 1

    def test_garbage_frame_closes_garbage_payload_answers(self, served_bin):
        # malformed HEADER -> close (stream unusable); malformed PAYLOAD
        # in a well-formed frame -> in-band error, connection stays up
        addr = served_bin.binary_address
        s1 = socket.create_connection(addr, timeout=10)
        try:
            s1.sendall(b"GET /v1/health HTTP/1.1\r\n\r\n")
            assert s1.recv(65536) == b""     # closed, no reply bytes
        finally:
            s1.close()
        s2 = socket.create_connection(addr, timeout=10)
        try:
            s2.sendall(framing.pack_frame(framing.OP_SWEEP, 1,
                                          b"not a codec message"))
            p = framing.FrameParser()
            got = {}
            while 1 not in got:
                p.feed(s2.recv(65536))
                for f in p.frames():
                    got[f.req_id] = f
            assert got[1].flags & framing.FLAG_ERROR
            name, _, _ = codec.decode_error(got[1].payload)
            assert name == "WireFormatError"
            # same socket still serves: framing stayed in sync
            s2.sendall(framing.pack_frame(framing.OP_HEALTH, 2, b""))
            while 2 not in got:
                p.feed(s2.recv(65536))
                for f in p.frames():
                    got[f.req_id] = f
            assert codec.decode_json(got[2].payload)["status"] == "ok"
        finally:
            s2.close()

    def test_overload_shed_is_typed_over_binary(self):
        with PredictionServer(port=0, binary_port=0,
                              max_queue_depth=0).start() as srv:
            c = bin_client(srv, max_retries=1)
            with pytest.raises(errors.ServerOverloaded):
                c.argmin(small_table("ovb"), "b200")
            c.close()

    def test_draining_sheds_sweeps_answers_probes(self):
        srv = PredictionServer(port=0, binary_port=0).start()
        try:
            c = bin_client(srv, max_retries=0, transport="binary")
            assert c.health()["draining"] is False   # socket now open
            srv.begin_drain()
            with pytest.raises(errors.ServerOverloaded, match="draining"):
                c.argmin(small_table("drainb"), "b200")
            assert c.health()["draining"] is True
            c.close()
        finally:
            srv.shutdown()

    def test_deadline_zero_budget_fails_without_io(self, served_bin):
        c = bin_client(served_bin)
        try:
            with pytest.raises(errors.DeadlineExceeded):
                c.argmin(small_table("dl0b"), "b200", deadline_s=0.0)
        finally:
            c.close()

    def test_subprocess_binary_banner_and_roundtrip(self):
        from repro.serve.subproc import start_server_subprocess, \
            stop_server_subprocess
        proc, host, port, bport = start_server_subprocess(binary=True)
        try:
            c = PredictionClient(host, port, binary_port=bport,
                                 timeout=60.0)
            table = small_table("subp")
            ref = sweep.argmin_table(table, B200, engine=fresh_engine())
            assert same_winner(c.argmin(table, "b200"), ref)
            assert c.cache_stats()["binary_requests"] >= 1
            c.close()
        finally:
            stop_server_subprocess(proc)


# ---------------------------------------------------------------------------
# cross-request dedup
# ---------------------------------------------------------------------------

class TestDedup:
    def test_concurrent_same_content_evaluates_once(self):
        co = Coalescer(fresh_engine(), window_s=0.15)
        try:
            table = small_table("dedup")
            ref = sweep.argmin_table(table, B200, engine=fresh_engine())
            results = []

            def run():
                results.append(co.submit("argmin", table, B200, None))

            threads = [threading.Thread(target=run) for _ in range(6)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert len(results) == 6
            assert all(same_winner(r[0], ref) for r in results)
            assert co.stats["deduped_requests"] == 5
            assert co.stats["dedup_rows_saved"] == 5 * len(table)
            # all-duplicates batches take the memoizing solo path: no
            # fused concat evaluation happened
            assert co.stats["fused_evaluations"] == 0
        finally:
            co.close()

    def test_dedup_preserves_per_request_names(self):
        # content_token ignores row names — two renamed copies dedup
        # into one evaluation, but each caller's winner must carry the
        # caller's OWN name
        co = Coalescer(fresh_engine(), window_s=0.15)
        try:
            ta = small_table("alpha")
            tb = small_table("bravo")
            assert ta.content_token() == tb.content_token()
            out = {}

            def run(key, table):
                out[key] = co.submit("argmin", table, B200, None)[0]

            threads = [threading.Thread(target=run, args=(k, t))
                       for k, t in (("a", ta), ("b", tb))]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert co.stats["deduped_requests"] == 1
            assert out["a"].name.startswith("alpha")
            assert out["b"].name.startswith("bravo")
            assert out["a"].index == out["b"].index
            assert out["a"].total == out["b"].total
        finally:
            co.close()

    def test_dedup_inside_mixed_fused_batch(self):
        # duplicates ride a fused batch with distinct companions: the
        # fused table carries each distinct content once
        co = Coalescer(fresh_engine(), window_s=0.15)
        try:
            tables = [small_table("m0"), small_table("m0"),
                      small_table("m1", 4096), small_table("m2", 1024)]
            eng = fresh_engine()
            refs = [sweep.argmin_table(t, B200, engine=eng)
                    for t in tables]
            out = [None] * 4

            def run(i):
                out[i] = co.submit("argmin", tables[i], B200, None)[0]

            threads = [threading.Thread(target=run, args=(i,))
                       for i in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert all(same_winner(a, b) for a, b in zip(out, refs))
            assert co.stats["deduped_requests"] == 1
            assert co.stats["fused_evaluations"] == 1
            # the fused evaluation priced 3 distinct tables, not 4
            assert co.stats["fused_rows"] == 3 * len(tables[0])
            assert co.stats["coalesced_requests"] == 4
        finally:
            co.close()

    def test_served_dedup_counters_flow_to_stats(self, served_bin):
        c = bin_client(served_bin)
        try:
            before = c.cache_stats()["coalescer_deduped_requests"]
            tabs = [small_table("svd")] * 8
            wins = c.argmin_many(tabs, "b200")
            ref = sweep.argmin_table(tabs[0], B200,
                                     engine=fresh_engine())
            assert all(same_winner(w, ref) for w in wins)
            after = c.cache_stats()["coalescer_deduped_requests"]
            assert after > before
        finally:
            c.close()


# ---------------------------------------------------------------------------
# adaptive fused-row budget
# ---------------------------------------------------------------------------

class TestAdaptiveBudget:
    def test_est_cost_units(self):
        plain = small_table("cost")
        assert Coalescer._est_cost(plain) == len(plain)
        scal = scalar_table("cost", 4)
        assert Coalescer._est_cost(scal) \
            == 4 * SCALAR_ROW_COST
        mixed = WorkloadTable.concat([plain, scal])
        assert Coalescer._est_cost(mixed) \
            == len(plain) + 4 * SCALAR_ROW_COST

    def test_mixed_batch_splits_but_answers_all_bit_identical(self):
        # the satellite's regression: scalar-fallback and vectorized
        # tables land in ONE drained batch under a budget that cannot
        # hold them all — packing must split, and every parked request
        # still answers bit-identically
        budget = len(small_table("x")) + 1    # one vectorized table max
        co = Coalescer(fresh_engine(), window_s=0.2,
                       max_fused_rows=budget)
        try:
            tables = [small_table("v0"), scalar_table("s0", 3),
                      small_table("v1", 4096), scalar_table("s1", 2),
                      small_table("v2", 1024)]
            eng = fresh_engine()
            refs = [sweep.argmin_table(t, B200, engine=eng)
                    for t in tables]
            out = [None] * len(tables)
            errs = []

            def run(i):
                try:
                    out[i] = co.submit("argmin", tables[i], B200,
                                       None)[0]
                except BaseException as e:    # noqa: BLE001
                    errs.append(e)

            threads = [threading.Thread(target=run, args=(i,))
                       for i in range(len(tables))]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert not errs
            assert all(same_winner(a, b) for a, b in zip(out, refs))
            # the budget forced splits: nothing fused 2+ tables
            assert co.stats["fused_evaluations"] == 0
            assert co.stats["batches"] >= 1
        finally:
            co.close()

    def test_scalar_cost_shrinks_fused_batches(self):
        # 5 scalar tables of 2 rows = 10 rows raw but 500 cost units: a
        # 300-unit budget must split them, a raw-row reading would not
        co = Coalescer(fresh_engine(), window_s=0.2,
                       max_fused_rows=6 * SCALAR_ROW_COST)
        try:
            tables = [scalar_table(f"sc{i}", 2, scale=1.0 + i)
                      for i in range(5)]
            eng = fresh_engine()
            refs = [sweep.argmin_table(t, B200, engine=eng)
                    for t in tables]
            out = [None] * 5

            def run(i):
                out[i] = co.submit("argmin", tables[i], B200, None)[0]

            threads = [threading.Thread(target=run, args=(i,))
                       for i in range(5)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert all(same_winner(a, b) for a, b in zip(out, refs))
            # 5 x 100-unit tables under a 300-unit budget: at least two
            # fused evaluations (3 + 2), never one batch of five
            assert co.stats["fused_evaluations"] >= 2
        finally:
            co.close()

    def test_server_bound_is_tunable_and_observable(self):
        with PredictionServer(port=0, binary_port=0,
                              max_fused_rows=777).start() as srv:
            assert srv.coalescer.max_fused_rows == 777
            c = bin_client(srv)
            try:
                assert c.cache_stats()["coalescer_max_fused_rows"] == 777
            finally:
                c.close()

    def test_default_bound_unchanged(self):
        with PredictionServer(port=0) as srv:
            assert srv.coalescer.max_fused_rows == MAX_FUSED_ROWS

    def test_per_request_hint_tightens_served_batches(self, served_bin):
        # hint=1: every table must evaluate alone even when pipelined
        # into one drained batch — and answers stay bit-identical
        c = bin_client(served_bin)
        try:
            tables = [small_table(f"h{j}", 1024 + 512 * j)
                      for j in range(4)]
            eng = fresh_engine()
            refs = [sweep.argmin_table(t, B200, engine=eng)
                    for t in tables]
            wins = c.argmin_many(tables, "b200", max_fused_rows=1)
            assert all(same_winner(a, b) for a, b in zip(wins, refs))
        finally:
            c.close()

    def test_invalid_hint_is_typed_error(self, served_bin):
        # client-side validation
        with pytest.raises(ValueError, match="max_fused_rows"):
            codec.encode_request("argmin", small_table("bad"), hw="b200",
                                 max_fused_rows=0)
        # server-side validation (a hand-crafted meta dodging the client
        # check): typed 400-class reply, not a 500 or a hang
        body = codec.encode_request("argmin", small_table("bad"),
                                    hw="b200")
        op, source, meta = codec.decode_request(body)
        meta["max_fused_rows"] = 0
        with pytest.raises(ValueError, match="max_fused_rows"):
            served_bin.answer_decoded(op, source, meta)
        meta["max_fused_rows"] = 2.5
        with pytest.raises(ValueError, match="max_fused_rows"):
            served_bin.answer_decoded(op, source, meta)

    def test_huge_hint_clamps_to_server_bound(self, served_bin):
        c = bin_client(served_bin)
        try:
            table = small_table("clamp")
            ref = sweep.argmin_table(table, B200, engine=fresh_engine())
            got = c.argmin(table, "b200",
                           max_fused_rows=MAX_FUSED_ROWS * 1000)
            assert same_winner(got, ref)
        finally:
            c.close()

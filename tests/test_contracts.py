"""Contract-linter tests: every rule fires on a true positive, stays
quiet on a true negative, suppressions demand justification, the wire
lock rejects non-additive codec changes, and — the tier-1 wiring — the
checkout itself lints clean.

Fixture style: each test writes a miniature repo under ``tmp_path`` and
runs :func:`repro.analysis.run_checks` against it with the one rule
under test, so fixtures prove the *rule* and the repo-wide test proves
the *repo*.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro import analysis
from repro.analysis.rules import wire_drift

REAL_ROOT = Path(analysis.repo_root())


def lint(tmp_path, files, rules, baseline=None):
    """Write ``{rel: source}`` under ``tmp_path`` and lint those files."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return analysis.run_checks(root=str(tmp_path),
                               paths=sorted(files), rules=rules,
                               baseline=baseline)


def rule_errors(report, rule_id):
    return [f for f in report.errors if f.rule == rule_id]


# ---------------------------------------------------------------------------
# SWEEP-LOOP
# ---------------------------------------------------------------------------

class TestSweepLoop:
    def test_fires_on_per_config_loop(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/bad.py": """\
            def sweep(cfgs, hw):
                out = []
                for c in cfgs:
                    out.append(predict(Workload(c), hw))
                totals = [predict(w, hw) for w in out]
                return totals
            """}, rules=["SWEEP-LOOP"])
        found = rule_errors(report, "SWEEP-LOOP")
        assert len(found) == 3          # Workload + 2x predict
        assert all("loop" in f.message for f in found)
        assert "predict_table" in found[0].hint

    def test_quiet_outside_loops_and_in_suites(self, tmp_path):
        report = lint(tmp_path, {
            "src/repro/core/ok.py": """\
                def one_off(cfg, hw):
                    return predict(Workload(cfg), hw)
                """,
            "src/repro/core/suites/inventory.py": """\
                KERNELS = [Workload(c) for c in NAMED_CASES]
                """,
        }, rules=["SWEEP-LOOP"])
        assert not rule_errors(report, "SWEEP-LOOP")


# ---------------------------------------------------------------------------
# FROZEN-MUT
# ---------------------------------------------------------------------------

class TestFrozenMut:
    def test_fires_on_frozen_mutation(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/bad.py": """\
            def poke(table, buf):
                table.cols[0, 1] = 9.0
                table.precision_codes[0] += 1
                buf.setflags(write=True)
                table.cols.resize((2, 2))
                table.wclass_codes = None
            """}, rules=["FROZEN-MUT"])
        found = rule_errors(report, "FROZEN-MUT")
        assert len(found) == 5
        assert any("setflags" in f.message for f in found)
        assert any("rebinding" in f.message for f in found)

    def test_quiet_on_local_buildup_and_freeze(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/ok.py": """\
            class Table:
                def __init__(self, cols):
                    cols[0, 0] = 1.0          # local array, still building
                    cols.flags.writeable = False
                    cols.setflags(write=False)
                    self.cols = cols          # constructor initializes
            """}, rules=["FROZEN-MUT"])
        assert not rule_errors(report, "FROZEN-MUT")


# ---------------------------------------------------------------------------
# LOOP-BLOCK
# ---------------------------------------------------------------------------

class TestLoopBlock:
    def test_fires_on_reachable_blocking_call(self, tmp_path):
        report = lint(tmp_path, {"src/repro/serve/binserver.py": """\
            import time

            class Frontend:
                def _loop(self):
                    self._readable()

                def _readable(self):
                    time.sleep(0.5)
                    self.fut.result()
                    self.sock.sendall(b"x")
            """}, rules=["LOOP-BLOCK"])
        found = rule_errors(report, "LOOP-BLOCK")
        assert len(found) == 3
        assert all("_loop -> _readable" in f.message for f in found)

    def test_quiet_off_loop_and_with_timeouts(self, tmp_path):
        report = lint(tmp_path, {"src/repro/serve/binserver.py": """\
            import time

            class Frontend:
                def _loop(self):
                    self._handle()

                def _handle(self):
                    self.fut.result(timeout=0.1)

                    def on_done(res):      # runs on the coalescer thread
                        time.sleep(1)
                    self.coalescer.submit_async(on_done)

                def admin_snapshot(self):  # not reachable from _loop
                    time.sleep(1)
            """}, rules=["LOOP-BLOCK"])
        assert not rule_errors(report, "LOOP-BLOCK")

    def test_other_modules_ignored(self, tmp_path):
        report = lint(tmp_path, {"src/repro/serve/worker.py": """\
            import time

            def _loop():
                time.sleep(1)
            """}, rules=["LOOP-BLOCK"])
        assert not rule_errors(report, "LOOP-BLOCK")


# ---------------------------------------------------------------------------
# FORK-LOCK
# ---------------------------------------------------------------------------

class TestForkLock:
    def test_fires_on_module_lock_and_singleton(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/bad.py": """\
            import threading

            _LOCK = threading.Lock()

            class Registry:
                def __init__(self):
                    self._lock = threading.RLock()

            REGISTRY = Registry()
            """}, rules=["FORK-LOCK"])
        found = rule_errors(report, "FORK-LOCK")
        assert len(found) == 2
        assert any("singleton" in f.message for f in found)
        assert "register_at_fork" in found[0].hint

    def test_quiet_with_hook_or_instance_scope(self, tmp_path):
        report = lint(tmp_path, {
            "src/repro/core/hooked.py": """\
                import os, threading

                _LOCK = threading.Lock()

                def _reinit():
                    global _LOCK
                    _LOCK = threading.Lock()

                os.register_at_fork(after_in_child=_reinit)
                """,
            "src/repro/core/instances.py": """\
                import threading

                class Pool:
                    def __init__(self):
                        self._lock = threading.Lock()

                def make_pool():
                    return Pool()         # per-call, not module lifetime
                """,
        }, rules=["FORK-LOCK"])
        assert not rule_errors(report, "FORK-LOCK")


# ---------------------------------------------------------------------------
# METRIC-NAME
# ---------------------------------------------------------------------------

class TestMetricName:
    def test_fires_on_bad_family_label_and_dynamic_name(self, tmp_path):
        report = lint(tmp_path, {"src/repro/serve/bad.py": """\
            from repro.obs import metrics

            A = metrics.counter("requests_total", "outside namespace")
            B = metrics.counter("repro_serve_x_total", "h", color="red")
            C = metrics.counter("repro_serve_y_total", "h",
                                transport="carrier")
            D = metrics.counter(FAMILY, "computed name")
            """}, rules=["METRIC-NAME"])
        found = rule_errors(report, "METRIC-NAME")
        messages = " | ".join(f.message for f in found)
        assert "outside the repro_" in messages
        assert "'color'" in messages
        assert "'carrier'" in messages
        assert "not a string literal" in messages

    def test_cross_checks_expected_families(self, tmp_path):
        files = {
            "src/repro/serve/mod.py": """\
                from repro.obs import metrics
                M = metrics.counter("repro_serve_new_total", "h",
                                    transport="http")
                """,
            "tests/test_obs.py": """\
                EXPECTED_FAMILIES = [
                    "repro_serve_new_total",
                    "repro_serve_gone_total",
                ]
                """,
        }
        report = lint(tmp_path, files, rules=["METRIC-NAME"])
        found = rule_errors(report, "METRIC-NAME")
        assert len(found) == 1           # declared+listed is fine
        assert "repro_serve_gone_total" in found[0].message
        assert "append-only" in found[0].message

    def test_new_family_must_be_listed(self, tmp_path):
        report = lint(tmp_path, {
            "src/repro/serve/mod.py": """\
                from repro.obs import metrics
                M = metrics.counter("repro_serve_new_total", "h")
                """,
            "tests/test_obs.py": "EXPECTED_FAMILIES = []\n",
        }, rules=["METRIC-NAME"])
        found = rule_errors(report, "METRIC-NAME")
        assert len(found) == 1
        assert "EXPECTED_FAMILIES" in found[0].message


# ---------------------------------------------------------------------------
# WIRE-DRIFT
# ---------------------------------------------------------------------------

def _copy_wire_files(tmp_path):
    for rel in (wire_drift.CODEC_REL, wire_drift.FRAMING_REL,
                wire_drift.LOCK_REL):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        dst.write_text((REAL_ROOT / rel).read_text())


class TestWireDrift:
    def run(self, tmp_path):
        return analysis.run_checks(root=str(tmp_path),
                                   paths=["src/repro/serve"],
                                   rules=["WIRE-DRIFT"])

    def test_quiet_when_lock_matches_source(self, tmp_path):
        _copy_wire_files(tmp_path)
        assert self.run(tmp_path).ok

    def test_non_additive_renumber_fails_with_version_bump(self, tmp_path):
        _copy_wire_files(tmp_path)
        codec = tmp_path / wire_drift.CODEC_REL
        codec.write_text(codec.read_text().replace(
            "MSG_TABLE = 1\n", "MSG_TABLE = 12\n"))
        found = rule_errors(self.run(tmp_path), "WIRE-DRIFT")
        assert len(found) == 1
        assert "renumbered" in found[0].message
        assert "bump WIRE_VERSION" in found[0].hint

    def test_removed_message_fails(self, tmp_path):
        _copy_wire_files(tmp_path)
        codec = tmp_path / wire_drift.CODEC_REL
        codec.write_text(codec.read_text().replace(
            "MSG_CALREQ = 11\n", ""))
        found = rule_errors(self.run(tmp_path), "WIRE-DRIFT")
        assert len(found) == 1
        assert "removed" in found[0].message

    def test_repacked_header_fails(self, tmp_path):
        _copy_wire_files(tmp_path)
        framing = tmp_path / wire_drift.FRAMING_REL
        framing.write_text(framing.read_text().replace(
            '"<4sBBHIQf"', '"<4sBBHIQd"'))
        found = rule_errors(self.run(tmp_path), "WIRE-DRIFT")
        assert len(found) == 1
        assert "framing.header_format" in found[0].message

    def test_additive_change_fails_until_lock_refreshed(self, tmp_path):
        _copy_wire_files(tmp_path)
        codec = tmp_path / wire_drift.CODEC_REL
        codec.write_text(codec.read_text()
                         + "\nMSG_FUTURE = 12\n")
        found = rule_errors(self.run(tmp_path), "WIRE-DRIFT")
        assert len(found) == 1
        assert "--update-wire-lock" in found[0].hint
        # refreshing the lock (the documented fix) clears the finding
        modules = analysis.core.collect_modules(
            str(tmp_path), ["src/repro/serve"])
        project = analysis.Project(str(tmp_path), modules)
        schema, _ = wire_drift.extract_schema(project)
        wire_drift.write_lock(str(tmp_path), schema)
        assert self.run(tmp_path).ok

    def test_missing_lock_fails(self, tmp_path):
        _copy_wire_files(tmp_path)
        (tmp_path / wire_drift.LOCK_REL).unlink()
        found = rule_errors(self.run(tmp_path), "WIRE-DRIFT")
        assert len(found) == 1
        assert "missing" in found[0].message


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

BAD_MUT = """\
    def poke(table):
        table.cols[0] = 1.0{comment}
"""


class TestSuppressions:
    def test_justified_allow_suppresses(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/bad.py": BAD_MUT.format(
            comment="  # repro: allow[FROZEN-MUT] test fixture resets "
                    "a scratch table")}, rules=["FROZEN-MUT"])
        assert report.ok
        supp = [f for f in report.findings if f.suppressed]
        assert len(supp) == 1
        assert supp[0].justification.startswith("test fixture")

    def test_standalone_allow_above_the_line(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/bad.py": """\
            def poke(table):
                # repro: allow[FROZEN-MUT] scratch table, never cached
                table.cols[0] = 1.0
            """}, rules=["FROZEN-MUT"])
        assert report.ok

    def test_bare_allow_is_an_error_and_does_not_suppress(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/bad.py": BAD_MUT.format(
            comment="  # repro: allow[FROZEN-MUT]")}, rules=["FROZEN-MUT"])
        rules = {f.rule for f in report.errors}
        assert rules == {"FROZEN-MUT", "SUPPRESS"}   # finding still gates
        meta = rule_errors(report, "SUPPRESS")[0]
        assert "no justification" in meta.message

    def test_wrong_rule_id_does_not_suppress(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/bad.py": BAD_MUT.format(
            comment="  # repro: allow[SWEEP-LOOP] wrong id")},
            rules=["FROZEN-MUT"])
        assert rule_errors(report, "FROZEN-MUT")

    def test_unused_allow_warns(self, tmp_path):
        report = lint(tmp_path, {"src/repro/core/ok.py": """\
            X = 1  # repro: allow[FROZEN-MUT] nothing here violates it
            """}, rules=["FROZEN-MUT"])
        assert report.ok                              # warning, not error
        warn = report.unsuppressed(analysis.WARNING)
        assert len(warn) == 1 and warn[0].rule == "SUPPRESS-UNUSED"

    def test_baseline_grandfathers_findings(self, tmp_path):
        files = {"src/repro/core/bad.py": BAD_MUT.format(comment="")}
        report = lint(tmp_path, files, rules=["FROZEN-MUT"])
        assert not report.ok
        base = tmp_path / "baseline.json"
        base.write_text(json.dumps(report.to_json()))
        report2 = lint(tmp_path, files, rules=["FROZEN-MUT"],
                       baseline=str(base))
        assert report2.ok
        assert all(f.justification == "grandfathered by baseline"
                   for f in report2.findings if f.suppressed)


# ---------------------------------------------------------------------------
# PARSE meta-rule
# ---------------------------------------------------------------------------

def test_unparseable_file_is_reported(tmp_path):
    report = lint(tmp_path, {"src/repro/core/broken.py": "def f(:\n"},
                  rules=["FROZEN-MUT"])
    assert [f.rule for f in report.errors] == ["PARSE"]


# ---------------------------------------------------------------------------
# CI gate: the checkout itself lints clean (tier-1 wiring)
# ---------------------------------------------------------------------------

def test_repo_lints_clean():
    report = analysis.run_checks()
    assert report.ok, "\n" + report.render(verbose=False)
    for f in report.findings:
        if f.suppressed:
            assert f.justification, f.render()


def test_check_contracts_gate_passes():
    root = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_contracts", "-q"],
        cwd=root, env=env, capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "check_contracts: PASS" in out.stdout

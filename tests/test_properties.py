"""Property-based tests (hypothesis) on the analytical models' invariants."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import blackwell, cache, calibrate, cdna3, collectives, \
    generic, hardware, predict, roofline, tpu, validate
from repro.core.workload import Segment, TileConfig, Workload, \
    gemm_workload, streaming_workload

HW_B = hardware.B200
HW_M = hardware.MI300A
HW_T = hardware.TPU_V5E

ALL_HW = [HW_B, HW_M, HW_T, hardware.H200, hardware.MI250X]

pos_floats = st.floats(min_value=1e3, max_value=1e15, allow_nan=False,
                       allow_infinity=False)
wclasses = st.sampled_from(["memory", "compute", "balanced", "stencil"])


def mk_workload(flops, nbytes, wclass, irregular=False):
    return Workload(name=f"w_{wclass}", wclass=wclass, flops=flops,
                    bytes=nbytes, precision="fp32",
                    working_set_bytes=nbytes, irregular=irregular)


@given(flops=pos_floats, nbytes=pos_floats, wclass=wclasses)
@settings(max_examples=60, deadline=None)
def test_predictions_positive_and_finite(flops, nbytes, wclass):
    w = mk_workload(flops, nbytes, wclass)
    for hw in ALL_HW:
        t = predict.predict(w, hw).total
        assert t > 0 and math.isfinite(t)
        t_roof = roofline.predict(w, hw).total
        assert t_roof >= 0 and math.isfinite(t_roof)


@given(flops=pos_floats, nbytes=pos_floats, wclass=wclasses,
       factor=st.floats(min_value=1.5, max_value=100.0))
@settings(max_examples=60, deadline=None)
def test_monotone_in_bytes(flops, nbytes, wclass, factor):
    """More bytes never makes any model predict faster."""
    w1 = mk_workload(flops, nbytes, wclass)
    w2 = mk_workload(flops, nbytes * factor, wclass)
    for hw in ALL_HW:
        assert predict.predict(w2, hw).total >= \
            predict.predict(w1, hw).total * 0.999


@given(flops=pos_floats, nbytes=pos_floats, wclass=wclasses,
       factor=st.floats(min_value=1.5, max_value=100.0))
@settings(max_examples=60, deadline=None)
def test_monotone_in_flops(flops, nbytes, wclass, factor):
    """Monotone in FLOPs for the stage-centric models.

    NOTE: the CDNA wavefront model is deliberately EXCLUDED — the paper's
    Eq. 12 divides (T_mem + T_comp) by (1 + eta(T_comp)), so adding compute
    can reduce predicted total time (better latency hiding).  See
    test_cdna_eq12_nonmonotone_is_paper_faithful below.
    """
    w1 = mk_workload(flops, nbytes, wclass)
    w2 = mk_workload(flops * factor, nbytes, wclass)
    for hw in (HW_B, HW_T, hardware.H200):
        assert predict.predict(w2, hw).total >= \
            predict.predict(w1, hw).total * 0.999


def test_cdna_eq12_nonmonotone_is_paper_faithful():
    """Documented paper quirk: under Eq. 9+12, a memory-bound kernel that
    gains a little compute is predicted FASTER (overlap grows faster than
    work).  We implement the equation as published."""
    w1 = mk_workload(1e3, 178352.0, "memory")
    w2 = mk_workload(14e3, 178352.0, "memory")
    t1 = predict.predict(w1, HW_M).total
    t2 = predict.predict(w2, HW_M).total
    assert t2 < t1  # the published non-monotonicity
    # but it is bounded: never more than the full overlap factor of 2
    assert t2 > t1 / 2.5


@given(ws=st.floats(min_value=1.0, max_value=1e13))
@settings(max_examples=100, deadline=None)
def test_hit_rate_in_unit_interval(ws):
    for hw in (HW_M, hardware.MI250X):
        h = cache.llc_hit_rate(ws, hw)
        assert 0.0 <= h <= 1.0


@given(ws=st.floats(min_value=1.0, max_value=1e13))
@settings(max_examples=100, deadline=None)
def test_blend_between_sustained_and_peak(ws):
    for hw in ALL_HW:
        b = cache.working_set_blend(ws, hw)
        lo = min(hw.hbm_sustained_bw, hw.hbm_peak_bw)
        hi = max(hw.hbm_sustained_bw, hw.hbm_peak_bw)
        assert lo - 1e-6 <= b <= hi + 1e-6


@given(n_wf=st.integers(min_value=1, max_value=64),
       tc=st.floats(min_value=0.0, max_value=1e3),
       tm=st.floats(min_value=1e-9, max_value=1e3))
@settings(max_examples=100, deadline=None)
def test_eta_overlap_unit_interval(n_wf, tc, tm):
    eta = cdna3.overlap_factor(n_wf, tc, tm)
    assert 0.0 <= eta <= 1.0


@given(vgpr=st.integers(min_value=1, max_value=1 << 20))
@settings(max_examples=100, deadline=None)
def test_occupancy_bounds(vgpr):
    n = cdna3.vgpr_limited_occupancy(vgpr, HW_M)
    assert 1 <= n <= HW_M.max_resident_warps
    # monotone non-increasing in VGPR pressure
    assert cdna3.vgpr_limited_occupancy(vgpr * 2, HW_M) <= n


@given(n_exec=st.integers(min_value=1, max_value=10000),
       nbytes=pos_floats)
@settings(max_examples=50, deadline=None)
def test_segment_scales_linearly_with_n_exec(n_exec, nbytes):
    from repro.core import segments as seg_mod
    w = streaming_workload("s", nbytes)
    t1 = seg_mod.predict_segment(Segment(workload=w, n_exec=1), HW_M).total
    tn = seg_mod.predict_segment(Segment(workload=w, n_exec=n_exec),
                                 HW_M).total
    assert tn == pytest.approx(n_exec * t1, rel=1e-6)


@given(nbytes=st.floats(min_value=1e3, max_value=1e12),
       op=st.sampled_from(list(collectives.RING_FACTORS)),
       axis=st.sampled_from(["pod", "data", "model"]))
@settings(max_examples=100, deadline=None)
def test_collective_time_nonnegative_and_linear(nbytes, op, axis):
    mesh = collectives.MeshSpec(axes=(("pod", 2), ("data", 16),
                                      ("model", 16)))
    t = collectives.collective_time(op, nbytes, axis, mesh, HW_T)
    t2 = collectives.collective_time(op, 2 * nbytes, axis, mesh, HW_T)
    assert t >= 0
    assert t2 == pytest.approx(2 * t, rel=1e-9)


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_calibration_identity_when_unit(data):
    """Calibration with all multipliers 1.0 must be a no-op."""
    flops = data.draw(pos_floats)
    nbytes = data.draw(pos_floats)
    w = mk_workload(flops, nbytes, "memory")
    cal = calibrate.Calibration()
    t0 = predict.predict(w, HW_M).total
    t1 = predict.predict(w, HW_M, calibration=cal).total
    assert t0 == pytest.approx(t1)


@given(seed=st.integers(min_value=0, max_value=1000))
@settings(max_examples=20, deadline=None)
def test_train_holdout_partition(seed):
    """Split is a partition: disjoint, covering, deterministic."""
    from repro.core.suites import mi300a_microbench, split as suite_split
    ws, meas = suite_split(mi300a_microbench.suite())
    tr, ho = calibrate.train_holdout_split(ws, meas, seed=seed)
    assert set(tr) | set(ho) == set(range(len(ws)))
    assert set(tr) & set(ho) == set()
    tr2, ho2 = calibrate.train_holdout_split(ws, meas, seed=seed)
    assert tr == tr2 and ho == ho2


def test_per_case_calibration_roundtrip_exact():
    """Fitted per-case multipliers reproduce measured exactly (pre-quantize)."""
    from repro.core.suites import b200_microbench, split as suite_split
    ws, meas = suite_split(b200_microbench.suite())

    def pf(w):
        return predict.predict(w, HW_B)
    cal = calibrate.fit_per_case(ws, meas, pf)
    for w, m in zip(ws, meas):
        t = predict.predict(w, HW_B, calibration=cal).total
        assert t == pytest.approx(m, rel=1e-9)


def test_holdout_no_leakage():
    """Per-class calibration fitted on train split: holdout MAE must be
    finite and reported separately (the paper's discipline)."""
    from repro.core.suites import mi300a_microbench, split as suite_split
    ws, meas = suite_split(mi300a_microbench.suite())

    def pf(w):
        return predict.predict(w, HW_M)
    cal, report = calibrate.fit_with_holdout(ws, meas, pf, mode="class")
    assert report["n_train"] + report["n_holdout"] == len(ws)
    assert report["holdout_mae"] >= 0.0
    assert math.isfinite(report["holdout_mae"])


@given(flops=pos_floats, nbytes=pos_floats)
@settings(max_examples=50, deadline=None)
def test_mae_zero_iff_exact(flops, nbytes):
    assert validate.pct_error(flops, flops) == 0.0
    assert validate.mae_percent([flops, nbytes], [flops, nbytes]) == 0.0


@given(mult=st.floats(min_value=0.1, max_value=10.0),
       flops=pos_floats, nbytes=pos_floats, wclass=wclasses)
@settings(max_examples=50, deadline=None)
def test_calibration_scales_multiplicatively(mult, flops, nbytes, wclass):
    w = mk_workload(flops, nbytes, wclass)
    cal = calibrate.Calibration(global_scale=mult)
    t0 = predict.predict(w, HW_M).total
    t1 = predict.predict(w, HW_M, calibration=cal).total
    assert t1 == pytest.approx(mult * t0, rel=1e-9)


@given(b=st.floats(min_value=1e6, max_value=1e12))
@settings(max_examples=50, deadline=None)
def test_irregular_never_faster(b):
    """Obs. 2: irregular access degrades, never improves, predictions."""
    w_reg = mk_workload(b / 10, b, "memory", irregular=False)
    w_irr = mk_workload(b / 10, b, "memory", irregular=True)
    for hw in ALL_HW:
        assert predict.predict(w_irr, hw).total >= \
            predict.predict(w_reg, hw).total


@given(n=st.integers(min_value=128, max_value=2048))
@settings(max_examples=30, deadline=None)
def test_stage_model_dominates_roofline(n):
    """Structural claim: stage serialization always >= naive max() bound."""
    n = (n // 128) * 128 or 128
    w = gemm_workload(f"g{n}", n, n, n, precision="fp16")
    assert blackwell.predict(w, HW_B).total >= \
        roofline.predict(w, HW_B).total

"""Substrate tests: optimizer, schedules, data pipeline, checkpointing
(incl. async + elastic reshard), gradient compression, end-to-end training
loss decrease, and greedy generation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data import SyntheticLMData, make_batch_specs
from repro.models import build
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    compress_int8, cosine_schedule, decompress_int8, \
    error_feedback_update, linear_schedule, wsd_schedule
from repro.train import checkpoint as ckpt
from repro.train.serve_step import greedy_generate
from repro.train.train_step import init_state, make_train_step

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=128)


class TestOptim:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(params, grads, state, lr=0.05,
                                            weight_decay=0.0)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.2

    def test_moment_dtype_bf16(self):
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = adamw_init(params, moment_dtype="bfloat16")
        assert state["mu"]["w"].dtype == jnp.bfloat16

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
        assert abs(cn - 1.0) < 1e-5
        assert float(norm) > 1.0

    def test_schedules_shape(self):
        for sched in (linear_schedule(1.0, 10, 100),
                      cosine_schedule(1.0, 10, 100),
                      wsd_schedule(1.0, 10, 100)):
            assert float(sched(0)) == pytest.approx(0.0, abs=1e-6)
            assert float(sched(10)) == pytest.approx(1.0, rel=1e-3)
            assert float(sched(99)) < 0.5

    def test_wsd_has_stable_plateau(self):
        sched = wsd_schedule(1.0, 10, 1000, decay_fraction=0.1)
        # stable phase: constant at peak
        assert float(sched(500)) == pytest.approx(1.0)
        assert float(sched(880)) == pytest.approx(1.0)
        # decay phase: rapidly down
        assert float(sched(990)) < 0.3


class TestGradCompression:
    def test_roundtrip_small_error(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s = compress_int8(g)
        deq = decompress_int8(q, s)
        assert q.dtype == jnp.int8
        rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
        assert rel < 0.02

    def test_error_feedback_unbiased_over_steps(self):
        """With constant gradient, EF-compressed updates average to the
        true gradient (residual stays bounded)."""
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (256,)) * 1e-3}
        res = {"w": jnp.zeros((256,), jnp.float32)}
        acc = jnp.zeros((256,))
        n = 50
        for _ in range(n):
            deq, res = error_feedback_update(g, res)
            acc = acc + deq["w"]
        err = float(jnp.linalg.norm(acc / n - g["w"])
                    / jnp.linalg.norm(g["w"]))
        assert err < 0.05
        assert float(jnp.linalg.norm(res["w"])) < \
            float(jnp.linalg.norm(g["w"])) * 2


class TestData:
    def test_deterministic_and_seekable(self):
        data = SyntheticLMData(TINY, batch=4, seq_len=32, seed=7)
        b1 = data.batch_at(10)
        b2 = data.batch_at(10)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = data.batch_at(11)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_next_tokens(self):
        data = SyntheticLMData(TINY, batch=2, seq_len=16)
        b = data.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)

    def test_prefetch_iterator(self):
        data = SyntheticLMData(TINY, batch=2, seq_len=8)
        it = data.iter_batches(start_step=5)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"],
                                      data.batch_at(5)["tokens"])

    def test_batch_specs_match_real_batches(self):
        specs = make_batch_specs(TINY, batch=4, seq_len=32)
        data = SyntheticLMData(TINY, batch=4, seq_len=32)
        b = data.batch_at(0)
        for k, spec in specs.items():
            assert tuple(b[k].shape) == tuple(spec.shape), k


class TestCheckpoint:
    def test_roundtrip_exact(self, tmp_path):
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        p = str(tmp_path / "ckpt_000001")
        ckpt.save(p, tree, step=1)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            tree)
        restored, manifest = ckpt.restore(p, like)
        assert manifest["step"] == 1
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_corruption_detected(self, tmp_path):
        tree = {"a": jnp.arange(8, dtype=jnp.float32)}
        p = str(tmp_path / "ckpt_000001")
        ckpt.save(p, tree)
        man = ckpt.load_manifest(p)
        man["leaves"]["a"]["hash"] = "0" * 32
        import json
        with open(os.path.join(p, "manifest.json"), "w") as f:
            json.dump(man, f)
        with pytest.raises(IOError):
            ckpt.restore(p, tree)

    def test_shape_mismatch_rejected(self, tmp_path):
        tree = {"a": jnp.zeros((4,))}
        p = str(tmp_path / "ckpt_000001")
        ckpt.save(p, tree)
        with pytest.raises(ValueError):
            ckpt.restore(p, {"a": jnp.zeros((5,))})

    def test_async_save(self, tmp_path):
        tree = {"a": jnp.arange(100, dtype=jnp.float32)}
        p = str(tmp_path / "ckpt_000002")
        saver = ckpt.AsyncCheckpointer()
        saver.save(p, tree, step=2)
        saver.wait()
        restored, man = ckpt.restore(p, tree)
        assert man["step"] == 2

    def test_latest_step_dir_and_retention(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path / f"ckpt_{s:06d}"), tree, step=s,
                      keep_last=2)
        latest = ckpt.latest_step_dir(str(tmp_path))
        assert latest.endswith("ckpt_000004")
        remaining = sorted(d for d in os.listdir(tmp_path)
                           if d.startswith("ckpt_"))
        assert remaining == ["ckpt_000003", "ckpt_000004"]

    def test_elastic_reshard_across_device_counts(self, tmp_path):
        """Save unsharded, restore with an explicit (1-device) sharding —
        the elastic path; multi-device resharding is exercised in
        tests/test_distributed.py subprocesses."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        p = str(tmp_path / "ckpt_000001")
        ckpt.save(p, tree)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = ckpt.restore(p, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


class TestEndToEnd:
    def test_loss_decreases(self):
        model = build(TINY)
        state = init_state(model, jax.random.PRNGKey(0))
        data = SyntheticLMData(TINY, batch=8, seq_len=32)
        step = jax.jit(make_train_step(model, lr=3e-3))
        losses = []
        for i in range(30):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        first = sum(losses[:5]) / 5
        last = sum(losses[-5:]) / 5
        assert last < first - 0.25, (first, last)

    def test_grad_accum_matches_full_batch(self):
        """microbatches=2 must equal the full-batch gradient step."""
        model = build(TINY)
        state0 = init_state(model, jax.random.PRNGKey(0))
        data = SyntheticLMData(TINY, batch=8, seq_len=16)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        s1, m1 = jax.jit(make_train_step(model, lr=1e-2))(state0, batch)
        s2, m2 = jax.jit(make_train_step(model, lr=1e-2,
                                         microbatches=2))(state0, batch)
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-5, rtol=2e-4)

    def test_compressed_grads_still_learn(self):
        model = build(TINY)
        state = init_state(model, jax.random.PRNGKey(0),
                           compress_grads=True)
        data = SyntheticLMData(TINY, batch=8, seq_len=32)
        step = jax.jit(make_train_step(model, lr=3e-3,
                                       compress_grads=True))
        losses = []
        for i in range(25):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert sum(losses[-5:]) / 5 < sum(losses[:5]) / 5 - 0.2

    def test_greedy_generate_shapes(self):
        model = build(TINY)
        params = model.init(jax.random.PRNGKey(0))
        prompt = jnp.ones((2, 8), jnp.int32)
        out = greedy_generate(model, params, prompt, max_new=5)
        assert out.shape == (2, 5)
        assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < TINY.vocab))

    def test_train_resume_from_checkpoint_exact(self, tmp_path):
        """Train 5 steps, checkpoint, train 5 more; vs. train 10 straight:
        identical params (deterministic data + saved step)."""
        model = build(TINY)
        data = SyntheticLMData(TINY, batch=4, seq_len=16)
        step = jax.jit(make_train_step(model, lr=1e-3))

        def run(state, start, n):
            for i in range(start, start + n):
                batch = {k: jnp.asarray(v)
                         for k, v in data.batch_at(i).items()}
                state, _ = step(state, batch)
            return state

        s_full = run(init_state(model, jax.random.PRNGKey(0)), 0, 10)
        s_half = run(init_state(model, jax.random.PRNGKey(0)), 0, 5)
        p = str(tmp_path / "ckpt_000005")
        ckpt.save(p, s_half, step=5)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s_half)
        s_restored, man = ckpt.restore(p, like)
        s_resumed = run(s_restored, man["step"], 5)
        for a, b in zip(jax.tree.leaves(s_full["params"]),
                        jax.tree.leaves(s_resumed["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

"""Streaming sharded sweep tests.

Covers: LatticeSpec lazy construction (cartesian / tile-lattice / concat)
chunk-for-chunk byte parity with the materialized tables, the >2^31-row
materialization guard, streamed argmin/topk/pareto bit-identity with the
fused table reductions on all five routes (including ties landing exactly
on chunk boundaries and chunk sizes of 1 and > n_rows), tracemalloc-
verified O(chunk) peak memory, the sharded executor (process pool,
shared-memory tables, threaded fallback, worker-crash surfacing) and the
fork-safety of the module-level default engine."""
import os
import tracemalloc

import numpy as np
import pytest

from repro.core import autotune, collectives, hardware, parallel, sweep, \
    validate
from repro.core.workload import LatticeSpec, MAX_MATERIALIZE_ROWS, \
    TileConfig, WorkloadTable, gemm_workload, streaming_workload
from tests.test_sweep import HW_ALL, mixed_workloads, routes_for

needs_procs = pytest.mark.skipif(not parallel.processes_available(),
                                 reason="worker processes unavailable")


def fresh_engine():
    return sweep.SweepEngine(use_cache=False)


def big_cartesian(n_side=16):
    base = gemm_workload("lat", 8192, 8192, 8192, precision="fp16")
    return LatticeSpec.cartesian(
        base,
        k_tiles=[8 + 4 * i for i in range(n_side)],
        num_ctas=[32 + 8 * i for i in range(n_side)],
        tma_participants=[1, 2, 4, 8])


def same_winner(a, b):
    return (a.index == b.index and a.total == b.total and a.name == b.name
            and a.breakdown == b.breakdown
            and a.breakdown.detail == b.breakdown.detail)


def same_winners(xs, ys):
    return len(xs) == len(ys) and all(same_winner(a, b)
                                      for a, b in zip(xs, ys))


class TestLatticeSpec:
    def test_cartesian_spec_matches_materialized(self):
        base = streaming_workload("s", 1e9)
        grids = dict(bytes=[1e6, 1e9, 1e12], precision=["fp32", "fp64"],
                     wclass=["memory", "compute"],
                     tile=[TileConfig(64, 64, 16), TileConfig(128, 128, 32)],
                     concurrent_kernels=[1, 2, 4])
        spec = LatticeSpec.cartesian(base, **grids)
        full = WorkloadTable.cartesian(base, **grids)
        assert spec.n_rows == len(full) == 72
        mat = spec.materialize()
        assert np.array_equal(mat.cols, full.cols)
        for size in (1, 7, spec.n_rows, spec.n_rows + 9):
            parts = list(spec.chunks(size))
            assert np.array_equal(np.vstack([p.cols for p in parts]),
                                  full.cols)
            assert [p.name(i) for p in parts for i in range(len(p))] \
                == [full.name(i) for i in range(len(full))]
            assert [p.precision_vocab[c] for p in parts
                    for c in p.precision_codes] \
                == [full.precision_vocab[c] for c in full.precision_codes]
            assert [p.wclass_vocab[c] for p in parts
                    for c in p.wclass_codes] \
                == [full.wclass_vocab[c] for c in full.wclass_codes]

    def test_cartesian_spec_rejects_unknown_field(self):
        with pytest.raises(ValueError, match="cannot sweep field"):
            LatticeSpec.cartesian(streaming_workload("s", 1e9), gemm=[None])

    def test_tile_lattice_spec_matches_table(self):
        base = gemm_workload("g", 4000, 4096, 4096, precision="fp16")
        tiles = [TileConfig(bm, bn, bk) for bm in (64, 128, 512)
                 for bn in (128, 256) for bk in (16, 64)]
        spec = LatticeSpec.tile_lattice(base, tiles)
        full = WorkloadTable.tile_lattice(base, tiles)
        assert np.array_equal(spec.materialize().cols, full.cols)
        parts = list(spec.chunks(5))
        assert np.array_equal(np.vstack([p.cols for p in parts]), full.cols)
        assert [p.name(i) for p in parts for i in range(len(p))] \
            == [full.name(i) for i in range(len(full))]

    def test_concat_spec_matches_materialized(self):
        base = gemm_workload("g", 2048, 2048, 2048, precision="fp16")
        children = [
            LatticeSpec.cartesian(base, k_tiles=[1, 2, 3, 4, 5]),
            LatticeSpec.from_table(WorkloadTable.from_workloads(
                mixed_workloads(hardware.B200, n=9, seed=3))),
            LatticeSpec.tile_lattice(base, [TileConfig(64, 64, 16),
                                            TileConfig(256, 256, 64)]),
        ]
        spec = LatticeSpec.concat(children)
        full = spec.materialize()
        assert len(full) == 16
        for size in (1, 4, 6, 16, 40):
            parts = list(spec.chunks(size))
            assert np.array_equal(np.vstack([p.cols for p in parts]),
                                  full.cols), size
            assert [p.name(i) for p in parts for i in range(len(p))] \
                == [full.name(i) for i in range(len(full))], size
            assert [p.precision_vocab[c] for p in parts
                    for c in p.precision_codes] \
                == [full.precision_vocab[c] for c in full.precision_codes]

    def test_n_rows_without_materializing(self):
        spec = LatticeSpec.cartesian(
            streaming_workload("s", 1e9),
            bytes=list(range(1 << 11)), num_loads=list(range(1 << 11)),
            k_tiles=list(range(1 << 10)))
        assert spec.n_rows == 1 << 32 > MAX_MATERIALIZE_ROWS
        assert spec.estimated_bytes() > 2 ** 31 * 200
        mid = spec.chunk(1 << 31, (1 << 31) + 4)   # lazy windows still work
        assert len(mid) == 4

    def test_materialize_guard_reports_bytes_and_streaming(self):
        spec = LatticeSpec.cartesian(
            streaming_workload("s", 1e9),
            bytes=list(range(1 << 11)), num_loads=list(range(1 << 11)),
            k_tiles=list(range(1 << 10)))
        with pytest.raises(ValueError) as ei:
            spec.materialize()
        msg = str(ei.value)
        assert "GB" in msg and "LatticeSpec" in msg and "stream" in msg
        with pytest.raises(ValueError, match="LatticeSpec"):
            WorkloadTable.cartesian(
                streaming_workload("s", 1e9),
                bytes=list(range(1 << 11)), num_loads=list(range(1 << 11)),
                k_tiles=list(range(1 << 10)))

    def test_table_chunks_are_global_named_views(self):
        ws = mixed_workloads(hardware.B200, n=10, seed=5)
        t = WorkloadTable.from_workloads(ws)
        parts = list(t.chunks(4))
        assert [len(p) for p in parts] == [4, 4, 2]
        assert [p.name(0) for p in parts] == [ws[0].name, ws[4].name,
                                              ws[8].name]
        assert parts[1].cols.base is not None      # view, not a copy
        lat = WorkloadTable.cartesian(streaming_workload("s", 1e9),
                                      bytes=[1.0, 2.0, 3.0, 4.0, 5.0])
        assert [p.name(0) for p in lat.chunks(2)] == ["s#0", "s#2", "s#4"]


class TestStreamingParity:
    @pytest.mark.parametrize("hw", HW_ALL, ids=lambda h: h.name)
    def test_stream_reductions_bit_identical_every_route(self, hw):
        ws = mixed_workloads(hw, n=45, seed=11)
        # duplicates at rows 6/7 and 34/35: with chunk_size=7 the first tie
        # straddles the 0|1 chunk boundary, the second the 4|5 boundary
        ws[7] = ws[6].replace()
        ws[35] = ws[34].replace()
        table = WorkloadTable.from_workloads(ws)
        for route in routes_for(hw):
            ref_arg = sweep.argmin_table(table, hw, model=route,
                                         engine=fresh_engine())
            ref_topk = sweep.topk_table(table, hw, 9, model=route,
                                        engine=fresh_engine())
            ref_par = sweep.pareto_table(table, hw, model=route,
                                         engine=fresh_engine())
            for cs in (1, 7, len(ws), len(ws) + 13):
                eng = fresh_engine()
                assert same_winner(
                    sweep.argmin_stream(table, hw, model=route,
                                        chunk_size=cs, engine=eng), ref_arg)
                assert same_winners(
                    sweep.topk_stream(table, hw, 9, model=route,
                                      chunk_size=cs, engine=eng), ref_topk)
                assert same_winners(
                    sweep.pareto_stream(table, hw, model=route,
                                        chunk_size=cs, engine=eng), ref_par)

    def test_all_tied_rows_resolve_to_lowest_indices(self):
        w = gemm_workload("g", 2048, 2048, 2048, precision="fp16")
        t = WorkloadTable.from_workloads([w] * 11)
        assert sweep.argmin_stream(t, hardware.B200, chunk_size=3).index == 0
        got = sweep.topk_stream(t, hardware.B200, 5, chunk_size=3)
        assert [x.index for x in got] == [0, 1, 2, 3, 4]

    def test_spec_stream_matches_materialized_table(self):
        spec = big_cartesian(8)                     # 8*8*4 = 256 rows
        table = spec.materialize()
        hw = hardware.B200
        ref = sweep.argmin_table(table, hw, engine=fresh_engine())
        for jobs in (None, 1):
            assert same_winner(
                sweep.argmin_stream(spec, hw, chunk_size=37, jobs=jobs),
                ref)
        assert same_winners(
            sweep.topk_stream(spec, hw, 6, chunk_size=37),
            sweep.topk_table(table, hw, 6, engine=fresh_engine()))
        assert same_winners(
            sweep.pareto_stream(spec, hw, chunk_size=37),
            sweep.pareto_table(table, hw, engine=fresh_engine()))

    def test_totals_stream_matches_predict_table(self):
        ws = mixed_workloads(hardware.MI300A, n=50, seed=13)
        t = WorkloadTable.from_workloads(ws)
        ref = fresh_engine().predict_table(t, hardware.MI300A).totals
        got = sweep.predict_totals_stream(t, hardware.MI300A, chunk_size=7)
        assert np.array_equal(got, ref)

    def test_calibration_applied_identically(self):
        from repro.core import calibrate
        hw = hardware.B200
        ws = mixed_workloads(hw, n=30, seed=17)
        cal = calibrate.Calibration(per_case={ws[4].name: 2.5},
                                    per_class={"memory": 1.5},
                                    global_scale=0.5)
        t = WorkloadTable.from_workloads(ws)
        ref = sweep.topk_table(t, hw, 5, calibration=cal,
                               engine=fresh_engine())
        got = sweep.topk_stream(t, hw, 5, calibration=cal, chunk_size=4,
                                engine=fresh_engine())
        assert same_winners(got, ref)

    def test_peak_memory_bounded_by_chunk(self):
        spec = big_cartesian(64)                    # 64*64*4 = 16384 rows
        full_bytes = spec.estimated_bytes()
        tracemalloc.start()
        try:
            sweep.argmin_stream(spec, hardware.B200, chunk_size=512)
            peak = tracemalloc.get_traced_memory()[1]
        finally:
            tracemalloc.stop()
        # O(chunk), not O(n): far below the materialized column matrix
        assert peak < full_bytes / 4, (peak, full_bytes)

    def test_empty_stream_raises(self):
        t = WorkloadTable.from_workloads(
            mixed_workloads(hardware.B200, n=4, seed=1))._slice(0, 0)
        with pytest.raises(ValueError, match="empty sweep"):
            sweep.argmin_stream(t, hardware.B200)

    def test_chunk_window_out_of_range_raises(self):
        spec = big_cartesian(4)                     # 64 rows
        for lo, hi in ((0, 65), (-1, 4), (70, 80), (5, 3)):
            with pytest.raises(ValueError, match="window"):
                spec.chunk(lo, hi)
        concat = LatticeSpec.concat([spec, spec])
        with pytest.raises(ValueError, match="window"):
            concat.chunk(0, 129)
        assert len(concat.chunk(5, 5)) == 0         # empty window is fine


class _StubRes:
    """Minimal TableResult stand-in for reducer-level unit tests."""

    def __init__(self, totals):
        self.totals = np.asarray(totals, dtype=np.float64)

    def field_totals(self, field):
        return self.totals

    def __getitem__(self, i):
        return ("tb", float(self.totals[i]))


class _StubTable:
    def name(self, i):
        return f"t#{i}"


def _feed(reducer, totals, chunk):
    """Stream synthetic totals through a reducer in `chunk`-row pieces."""
    table = _StubTable()
    for lo in range(0, len(totals), chunk):
        reducer.update(lo, table, _StubRes(totals[lo:lo + chunk]))
    return reducer


class TestReducerNaNSemantics:
    """NumPy's reductions have specific NaN orderings (np.argmin returns
    the first NaN; stable argsort puts NaNs last by index).  The streaming
    reducers must replicate them or the bit-identity contract breaks on a
    model bug that produces NaN."""

    CASES = [
        [5.0, 1.0, 7.0, 1.0, 3.0],
        [5.0, float("nan"), 7.0, 1.0, 3.0],
        [float("nan")] * 5,
        [2.0, float("nan"), float("nan"), 0.5, float("nan"), 9.0],
        [float("nan"), 4.0, 1.0],
    ]

    @pytest.mark.parametrize("totals", CASES)
    @pytest.mark.parametrize("chunk", [1, 2, 10])
    def test_argmin_matches_numpy(self, totals, chunk):
        red = _feed(sweep.ArgminStream(), totals, chunk)
        assert red.result().index == int(np.argmin(np.asarray(totals)))

    @pytest.mark.parametrize("totals", CASES)
    @pytest.mark.parametrize("chunk", [1, 2, 10])
    def test_topk_matches_stable_argsort(self, totals, chunk):
        for k in (1, 3, len(totals)):
            red = _feed(sweep.TopkStream(k), totals, chunk)
            ref = np.argsort(np.asarray(totals), kind="stable")[:k]
            assert [w.index for w in red.result()] == ref.tolist(), \
                (totals, chunk, k)

    @pytest.mark.parametrize("totals", CASES)
    def test_merge_matches_serial(self, totals):
        half = len(totals) // 2
        a = _feed(sweep.ArgminStream(), totals[:half], 2)
        b = sweep.ArgminStream()
        _feed_at(b, totals[half:], half, 2)
        a.merge(b)
        assert a.result().index == int(np.argmin(np.asarray(totals)))
        ta = _feed(sweep.TopkStream(3), totals[:half], 2)
        tb = sweep.TopkStream(3)
        _feed_at(tb, totals[half:], half, 2)
        ta.merge(tb)
        ref = np.argsort(np.asarray(totals), kind="stable")[:3]
        assert [w.index for w in ta.result()] == ref.tolist()

    def test_pareto_nan_sorted_last_like_argsort(self):
        pts = [3.0, float("nan"), 1.0, float("nan")]
        red = _feed(sweep.ParetoStream(objectives=("total",)), pts, 2)
        # no point dominates another through a NaN comparison, so every
        # row survives; ordering must match stable argsort (NaNs last)
        got = [w.index for w in red.result()]
        keep = np.flatnonzero(sweep._pareto_front_mask(
            np.asarray(pts).reshape(-1, 1)))
        ref = keep[np.argsort(np.asarray(pts)[keep], kind="stable")]
        assert got == ref.tolist()


def _feed_at(reducer, totals, base, chunk):
    table = _StubTable()
    for lo in range(0, len(totals), chunk):
        reducer.update(base + lo, table, _StubRes(totals[lo:lo + chunk]))
    return reducer


def _child_engine_stats():
    return sweep.default_engine().cache_stats()


def _hard_exit():
    os._exit(13)


class TestShardedExecutor:
    @needs_procs
    def test_sharded_matches_serial(self):
        spec = big_cartesian(16)                    # 1024 rows
        table = spec.materialize()
        hw = hardware.B200
        assert same_winner(
            sweep.argmin_stream(spec, hw, chunk_size=64, jobs=2),
            sweep.argmin_table(table, hw, engine=fresh_engine()))
        assert same_winners(
            sweep.topk_stream(spec, hw, 7, chunk_size=64, jobs=2),
            sweep.topk_table(table, hw, 7, engine=fresh_engine()))
        assert same_winners(
            sweep.pareto_stream(spec, hw, chunk_size=64, jobs=2),
            sweep.pareto_table(table, hw, engine=fresh_engine()))

    @needs_procs
    def test_shared_memory_table_path(self):
        ws = mixed_workloads(hardware.B200, n=120, seed=23)
        table = WorkloadTable.from_workloads(ws)
        shared = parallel.SharedTable(table)
        try:
            view, shms = parallel.SharedTable.attach(shared.handle)
            assert np.array_equal(view.cols, table.cols)
            assert view.name(5) == table.name(5)
            for s in shms:
                s.close()
        finally:
            shared.close(unlink=True)
        # end to end: table input -> shm transport -> sharded reduction
        assert same_winner(
            sweep.argmin_stream(table, hardware.B200, chunk_size=16,
                                jobs=2),
            sweep.argmin_table(table, hardware.B200,
                               engine=fresh_engine()))

    def test_threaded_fallback_matches(self):
        spec = big_cartesian(8)
        red = parallel.reduce_sharded(
            spec, hardware.B200, [sweep.ArgminStream], jobs=2,
            chunk_size=32, use_threads=True)
        assert same_winner(
            red[0].result(),
            sweep.argmin_table(spec.materialize(), hardware.B200,
                               engine=fresh_engine()))

    @needs_procs
    def test_worker_exception_surfaces(self):
        table = WorkloadTable.from_workloads(
            mixed_workloads(hardware.B200, n=64, seed=29))
        with pytest.raises(ValueError, match="unknown model route"):
            sweep.argmin_stream(table, hardware.B200, chunk_size=8,
                                jobs=2, model="nope")

    @needs_procs
    def test_worker_hard_crash_surfaces(self):
        from concurrent.futures.process import BrokenProcessPool
        with pytest.raises(BrokenProcessPool):
            parallel.map_jobs(_hard_exit, [(), ()], jobs=2,
                              use_threads=False)

    @needs_procs
    def test_fork_safe_default_engine_caches(self):
        eng = sweep.default_engine()
        table = WorkloadTable.from_workloads(
            mixed_workloads(hardware.B200, n=24, seed=31))
        eng.predict_table(table, hardware.B200)     # prime parent caches
        before = eng.cache_stats()
        assert before["table_entries"] >= 1
        # forked workers must start with EMPTY caches (no copy-on-write
        # reuse of parent state) ...
        for child_stats in parallel.map_jobs(_child_engine_stats, [(), ()],
                                             jobs=2, use_threads=False):
            assert child_stats["entries"] == 0
            assert child_stats["batch_entries"] == 0
            assert child_stats["table_entries"] == 0
            assert child_stats["hits"] == child_stats["misses"] == 0
        # ... and a full sharded reduction must leave the parent's engine
        # accounting untouched
        sweep.argmin_stream(table, hardware.B200, chunk_size=8, jobs=2)
        assert eng.cache_stats() == before

    @needs_procs
    def test_map_jobs_preserves_order(self):
        got = parallel.map_jobs(_square, [(i,) for i in range(20)], jobs=2)
        assert got == [i * i for i in range(20)]


def _square(x):
    return x * x


class TestConsumersStreamed:
    def test_select_tile_streamed_matches(self):
        base = gemm_workload("sel", 4096, 4096, 4096, precision="fp16")
        tiles = [TileConfig(bm, bn, bk) for bm in (64, 128, 256)
                 for bn in (64, 128) for bk in (16, 32)]
        ref = autotune.select_tile(base, hardware.B200, tiles,
                                   engine=fresh_engine())
        got = autotune.select_tile(base, hardware.B200, tiles,
                                   chunk_size=5)
        assert got == ref
        assert autotune.enumerate_tiles(base, hardware.B200, tiles,
                                        chunk_size=4) \
            == autotune.enumerate_tiles(base, hardware.B200, tiles,
                                        engine=fresh_engine())

    @needs_procs
    def test_enumerate_plans_chunked_and_sharded_match(self):
        mesh = collectives.MeshSpec(axes=(("data", 8), ("model", 4)))
        plans = [autotune.PlanCandidate(
            name=f"p{i}", mesh=mesh, tp_degree=4,
            microbatches=(i % 8) + 1,
            remat=["none", "block", "full"][i % 3]) for i in range(200)]
        kw = dict(model_flops=1e18, param_bytes=2e11,
                  activation_bytes=5e12, opt_state_bytes=4e11,
                  activation_peak_bytes=1e12)
        ref = autotune.enumerate_plans(plans, **kw)
        for costs in (autotune.enumerate_plans(plans, chunk_size=17, **kw),
                      autotune.enumerate_plans(plans, jobs=2, **kw)):
            assert [(c.plan.name, c.total_s, c.detail) for c in costs] \
                == [(c.plan.name, c.total_s, c.detail) for c in ref]

    def test_validate_suite_streamed_matches(self):
        ws = mixed_workloads(hardware.MI300A, n=36, seed=37)
        meas = [1e-5 * (i + 1) for i in range(len(ws))]
        ref = validate.validate_suite(hardware.MI300A, ws, meas)
        got = validate.validate_suite(hardware.MI300A, ws, meas,
                                      chunk_size=5)
        assert [(r.name, r.model_s, r.roofline_s) for r in ref.rows] \
            == [(r.name, r.model_s, r.roofline_s) for r in got.rows]

"""Distributed tests: run in SUBPROCESSES with 8 placeholder host devices
(the main test process must keep seeing 1 device).

Covers: sharding-rule specs, mesh construction, small-mesh lower+compile of
train/serve steps (tiny configs), elastic checkpoint resharding across
device counts, HLO collective parsing on real lowered programs.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestShardingRules:
    def test_param_specs_follow_naming(self):
        out = run_sub("""
            from jax.sharding import PartitionSpec as P
            from repro.distributed import sharding
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            params = {
                "tok_embed": jnp.zeros((128, 64)),
                "lm_head": jnp.zeros((64, 128)),
                "groups": {"b0": {"attn": {
                    "wq": jnp.zeros((3, 64, 64)),
                    "wo": jnp.zeros((3, 64, 64)),
                }}},
                "norm": jnp.zeros((64,)),
            }
            specs = sharding.param_specs(params, mesh=mesh)
            assert specs["tok_embed"] == P("model", "data"), specs["tok_embed"]
            assert specs["lm_head"] == P("data", "model")
            assert specs["groups"]["b0"]["attn"]["wq"] == \\
                P(None, "data", "model")
            assert specs["groups"]["b0"]["attn"]["wo"] == \\
                P(None, "model", "data")
            assert specs["norm"] == P(None)
            print("OK")
        """)
        assert "OK" in out

    def test_divisibility_guard(self):
        out = run_sub("""
            from jax.sharding import PartitionSpec as P
            from repro.distributed import sharding
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            # vocab 127 is prime: model axis (2) cannot shard it
            specs = sharding.param_specs(
                {"tok_embed": jnp.zeros((127, 64))}, mesh=mesh)
            assert specs["tok_embed"] == P(None, "data")
            # batch of 1 cannot shard over data axes
            b = sharding.batch_specs_tree(
                {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)},
                mesh=mesh)
            assert b["tokens"] == P(None, None)
            print("OK")
        """)
        assert "OK" in out

    def test_constrain_noop_outside_mesh(self):
        out = run_sub("""
            from repro.distributed.sharding import constrain
            x = jnp.ones((4, 4))
            y = constrain(x, ("batch", None))
            assert (x == y).all()
            print("OK")
        """)
        assert "OK" in out


class TestSmallMeshCompile:
    def test_train_step_lowers_on_2x2x2(self):
        """Tiny dense model: full train step lower+compile on a
        (pod, data, model) mesh; collective parsing sees real collectives."""
        out = run_sub("""
            from repro.configs.base import ModelConfig
            from repro.data import make_batch_specs
            from repro.distributed import sharding
            from repro.launch import hlo_analysis
            from repro.models import build
            from repro.train.train_step import init_state, make_train_step

            cfg = ModelConfig(name="t", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=256)
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            model = build(cfg)
            with sharding.use_mesh(mesh, {}):
                state = jax.eval_shape(
                    lambda k: init_state(model, k), jax.random.PRNGKey(0))
                st_sh = sharding.tree_shardings(
                    mesh, sharding.param_specs(state, mesh=mesh))
                bs = make_batch_specs(cfg, batch=8, seq_len=32)
                b_sh = sharding.tree_shardings(
                    mesh, sharding.batch_specs_tree(bs, mesh=mesh))
                step = make_train_step(model, lr=1e-3)
                compiled = jax.jit(step, in_shardings=(st_sh, b_sh)) \\
                    .lower(state, bs).compile()
            stats = hlo_analysis.analyze(compiled.as_text())
            assert stats.total_bytes > 0, "expected collectives on a mesh"
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            assert cost.get("flops", 0) > 0
            mem = compiled.memory_analysis()
            assert mem.temp_size_in_bytes > 0
            print("collectives:", sorted(stats.totals))
            print("OK")
        """)
        assert "OK" in out
        assert "all-" in out or "reduce" in out or "collective" in out

    def test_serve_step_lowers_with_cache_sharding(self):
        out = run_sub("""
            from repro.configs.base import ModelConfig
            from repro.distributed import sharding
            from repro.models import build
            from repro.train.serve_step import make_serve_step

            cfg = ModelConfig(name="t", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=256)
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            model = build(cfg)
            with sharding.use_mesh(mesh, {}):
                params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
                p_sh = sharding.tree_shardings(
                    mesh, sharding.param_specs(params, mesh=mesh))
                cache = model.init_cache(8, 64, abstract=True)
                c_sh = sharding.tree_shardings(
                    mesh, sharding.cache_specs_tree(cache, mesh=mesh))
                tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
                t_sh = sharding.tree_shardings(
                    mesh, sharding.batch_specs_tree(tok, mesh=mesh))
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                pos_sh = sharding.tree_shardings(
                    mesh, sharding.batch_specs_tree(pos, mesh=mesh))
                serve = make_serve_step(model)
                compiled = jax.jit(
                    serve, in_shardings=(p_sh, c_sh, t_sh, pos_sh)) \\
                    .lower(params, cache, tok, pos).compile()
            assert compiled is not None
            print("OK")
        """)
        assert "OK" in out

    def test_multi_device_execution_matches_single(self):
        """Actually EXECUTE a sharded train step on 8 devices and compare
        the loss with the unsharded single-device run."""
        out = run_sub("""
            from repro.configs.base import ModelConfig
            from repro.data import SyntheticLMData
            from repro.distributed import sharding
            from repro.models import build
            from repro.train.train_step import init_state, make_train_step

            cfg = ModelConfig(name="t", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=128)
            model = build(cfg)
            data = SyntheticLMData(cfg, batch=8, seq_len=32)
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
            state = init_state(model, jax.random.PRNGKey(0))
            step = make_train_step(model, lr=1e-3)
            _, m_single = jax.jit(step)(state, batch)

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            with sharding.use_mesh(mesh, {}):
                st_sh = sharding.tree_shardings(
                    mesh, sharding.param_specs(state, mesh=mesh))
                b_sh = sharding.tree_shardings(
                    mesh, sharding.batch_specs_tree(batch, mesh=mesh))
                state_d = jax.device_put(state, st_sh)
                batch_d = jax.device_put(batch, b_sh)
                _, m_dist = jax.jit(
                    step, in_shardings=(st_sh, b_sh))(state_d, batch_d)
            a = float(m_single["loss"]); b = float(m_dist["loss"])
            assert abs(a - b) / abs(a) < 1e-4, (a, b)
            print("OK", a, b)
        """)
        assert "OK" in out

    def test_elastic_checkpoint_reshard_8_to_4(self):
        """Save sharded on 8 devices, restore onto a 4-device mesh."""
        out = run_sub("""
            import tempfile
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train import checkpoint as ckpt

            tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
            mesh8 = jax.make_mesh((8,), ("data",))
            sh8 = {"w": NamedSharding(mesh8, P("data", None))}
            tree8 = jax.device_put(tree, sh8)
            d = tempfile.mkdtemp()
            path = d + "/ckpt_000001"
            ckpt.save(path, tree8, step=1)

            mesh4 = jax.make_mesh((4,), ("data",),
                                  devices=jax.devices()[:4])
            sh4 = {"w": NamedSharding(mesh4, P("data", None))}
            restored, man = ckpt.restore(path, tree, shardings=sh4)
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(tree["w"]))
            assert len(restored["w"].sharding.device_set) == 4
            print("OK")
        """)
        assert "OK" in out


class TestHLOAnalysis:
    def test_shape_bytes(self):
        from repro.launch import hlo_analysis as ha
        assert ha.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
        assert ha.shape_bytes("bf16[10]") == 20
        assert ha.shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
        assert ha.shape_bytes("token[]") == 0

    def test_analyze_counts_collectives(self):
        from repro.launch import hlo_analysis as ha
        text = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ag = f32[4096]{0} all-gather(%p0), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%p0), to_apply=%add
  ROOT %out = f32[1024]{0} copy(%ar)
}
"""
        stats = ha.analyze(text)
        assert stats.totals["all-gather"] == 4096.0
        assert stats.totals["all-reduce"] == 4096.0

    def test_while_trip_count_weighting(self):
        from repro.launch import hlo_analysis as ha
        text = """
HloModule test

%body.1 (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%p), to_apply=%add
}

ENTRY %main (x: f32[64]) -> f32[64] {
  %x = f32[64]{0} parameter(0)
  ROOT %w = f32[64]{0} while(%x), condition=%cond, body=%body.1,
      backend_config={"known_trip_count":{"n":"7"}}
}
"""
        stats = ha.analyze(text)
        assert stats.totals["all-reduce"] == pytest.approx(7 * 256.0)

    def test_default_multiplier_for_unannotated_while(self):
        from repro.launch import hlo_analysis as ha
        text = """
HloModule test

%body.2 (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%p), to_apply=%add
}

ENTRY %main (x: f32[64]) -> f32[64] {
  %x = f32[64]{0} parameter(0)
  ROOT %w = f32[64]{0} while(%x), condition=%cond, body=%body.2
}
"""
        stats = ha.analyze(text, default_while_multiplier=12)
        assert stats.totals["all-reduce"] == pytest.approx(12 * 256.0)


class TestProductionMeshConstruction:
    def test_both_meshes_in_subprocess(self):
        out = run_sub("""
            from repro.launch.mesh import make_production_mesh
            m1 = make_production_mesh()
            assert m1.axis_names == ("data", "model")
            assert dict(m1.shape) == {"data": 16, "model": 16}
            m2 = make_production_mesh(multi_pod=True)
            assert m2.axis_names == ("pod", "data", "model")
            assert m2.size == 512
            print("OK")
        """, devices=512)
        assert "OK" in out

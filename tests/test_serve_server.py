"""Prediction-server tests: endpoints, coalescing, pool reuse, bugfixes.

In-process server instances cover the fast tier: every op (argmin / topk /
pareto / predict_table) served over real loopback HTTP must be
bit-identical to its in-process sweep counterpart, concurrent small
requests must fuse into one columnar evaluation (and still answer each
request exactly), malformed bodies must come back as clean 400s, and the
engine's memo cache must serve replayed sweeps across requests.

The ``slow``-marked end-to-end test runs the acceptance criterion for
real: a separate server *process*, a >=10k-row wire table and a >=1M-row
lattice plan, winners bit-identical to ``argmin_table``/``argmin_stream``.

Also pins the satellite bugfixes: ``launch.serve --no-smoke`` reachable,
and spawn/pickled ``HardwareParams`` never inheriting a stale interned
cache token.
"""
import pickle
import threading
import time

import numpy as np
import pytest

from repro.core import hardware, parallel, sweep
from repro.core.workload import LatticeSpec, TileConfig, WorkloadTable, \
    gemm_workload, streaming_workload
from repro.serve import codec
from repro.serve.client import PredictionClient
from repro.serve.server import Coalescer, PredictionServer

pytestmark = pytest.mark.serve

B200 = hardware.B200
TILES = [TileConfig(bm, bn, bk) for bm in (64, 128, 256)
         for bn in (64, 128, 256) for bk in (16, 32, 64)]


def fresh_engine():
    return sweep.SweepEngine(use_cache=False)


def gemm_base(name="g", m=4096):
    return gemm_workload(name, m, 4096, 4096, precision="fp16")


def tile_table(n_shapes=4, tiles=TILES):
    parts = [WorkloadTable.tile_lattice(
        gemm_base(f"shape{j}", 2048 + 512 * j), tiles)
        for j in range(n_shapes)]
    return WorkloadTable.concat(parts)


def same_winner(a, b):
    return (a.index == b.index and a.name == b.name and a.total == b.total
            and a.breakdown == b.breakdown
            and a.breakdown.detail == b.breakdown.detail)


@pytest.fixture(scope="module")
def served():
    server = PredictionServer(port=0).start()
    client = PredictionClient(*server.address)
    yield server, client
    client.close()
    server.shutdown()


class TestEndpoints:
    def test_health(self, served):
        _, client = served
        h = client.health()
        assert h["status"] == "ok"
        assert h["wire_version"] == codec.WIRE_VERSION
        assert "b200" in h["hardware"]

    def test_argmin_topk_pareto_totals_bit_identical(self, served):
        _, client = served
        table = tile_table()
        assert same_winner(client.argmin(table, "b200"),
                           sweep.argmin_table(table, B200,
                                              engine=fresh_engine()))
        got = client.topk(table, "b200", 7)
        ref = sweep.topk_table(table, B200, 7, engine=fresh_engine())
        assert len(got) == 7
        assert all(same_winner(a, b) for a, b in zip(got, ref))
        got = client.pareto(table, "b200",
                            objectives=("compute", "memory"))
        ref = sweep.pareto_table(table, B200, engine=fresh_engine())
        assert all(same_winner(a, b) for a, b in zip(got, ref))
        tots = client.predict_totals(table, "b200")
        assert np.array_equal(
            tots, fresh_engine().predict_table(table, B200).totals)

    def test_model_override_and_other_hardware(self, served):
        _, client = served
        table = tile_table(n_shapes=1)
        for hw_name, model in (("b200", "roofline"), ("mi300a", None),
                               ("tpu_v5e", None)):
            got = client.argmin(table, hw_name, model=model)
            ref = sweep.argmin_table(table, hardware.get(hw_name),
                                     model=model, engine=fresh_engine())
            assert same_winner(got, ref)

    def test_streamed_spec_routes(self, served):
        _, client = served
        spec = LatticeSpec.cartesian(
            gemm_base(), k_tiles=[8 + i for i in range(32)],
            num_ctas=[32 + 8 * i for i in range(32)])
        assert same_winner(client.argmin(spec, "b200"),
                           sweep.argmin_stream(spec, B200))
        got = client.topk(spec, "b200", 5, chunk_size=100)
        ref = sweep.topk_stream(spec, B200, 5, chunk_size=100)
        assert all(same_winner(a, b) for a, b in zip(got, ref))
        tots = client.predict_totals(spec, "b200")
        assert np.array_equal(tots,
                              sweep.predict_totals_stream(spec, B200))

    def test_replay_hits_the_table_cache(self, served):
        _, client = served
        table = tile_table(n_shapes=2)
        client.argmin(table, "b200")
        hits = client.cache_stats()["hits"]
        again = client.argmin(table, "b200")
        assert client.cache_stats()["hits"] >= hits + len(table)
        assert same_winner(again, sweep.argmin_table(
            table, B200, engine=fresh_engine()))

    def test_clear_cache(self, served):
        _, client = served
        assert client.clear_cache() == {"cleared": True}
        assert client.cache_stats()["table_entries"] == 0

    def test_coalesce_opt_out(self, served):
        _, client = served
        table = tile_table(n_shapes=1)
        got = client.argmin(table, "b200", coalesce=False)
        assert same_winner(got, sweep.argmin_table(table, B200,
                                                   engine=fresh_engine()))

    def test_close_releases_every_threads_connection(self, served):
        # a shared client keeps one socket per thread; close() from the
        # main thread must release all of them, not just its own
        server, _ = served
        client = PredictionClient(*server.address)
        barrier = threading.Barrier(3)

        def hit():
            client.health()
            barrier.wait()
        threads = [threading.Thread(target=hit) for _ in range(2)]
        for t in threads:
            t.start()
        client.health()
        barrier.wait()
        for t in threads:
            t.join()
        conns = list(client._conns)
        assert len(conns) == 3
        client.close()
        assert client._conns == set()
        assert all(c.sock is None for c in conns)

    def test_topk_k0_round_trips_empty(self, served):
        # served k=0 must match topk_table/topk_stream (= []), not
        # coerce to k=1
        _, client = served
        table = tile_table(n_shapes=1)
        assert sweep.topk_table(table, B200, 0,
                                engine=fresh_engine()) == []
        assert client.topk(table, "b200", 0) == []
        spec = LatticeSpec.cartesian(gemm_base(),
                                     k_tiles=[8, 16], num_ctas=[32, 64])
        assert client.topk(spec, "b200", 0) == []


class TestErrors:
    def test_unknown_hardware_is_400(self, served):
        _, client = served
        with pytest.raises(codec.RemoteError, match="unknown hardware"):
            client.argmin(tile_table(1), "gtx1080")

    def test_malformed_body_is_400_not_a_crash(self, served):
        server, client = served
        import http.client
        conn = http.client.HTTPConnection(*server.address)
        try:
            for body in (b"", b"garbage", b"RPRW" + b"\x00" * 3):
                conn.request("POST", "/v1/argmin", body,
                             {"Content-Type": "application/x-repro-wire"})
                resp = conn.getresponse()
                data = resp.read()
                assert resp.status == 400
                with pytest.raises(codec.RemoteError):
                    codec.raise_if_error(data)
        finally:
            conn.close()
        assert client.health()["status"] == "ok"   # server survived

    def test_unknown_endpoint_is_404(self, served):
        server, _ = served
        import http.client
        conn = http.client.HTTPConnection(*server.address)
        try:
            conn.request("GET", "/v1/nope")
            assert conn.getresponse().status == 404
        finally:
            conn.close()

    def test_op_endpoint_mismatch_is_400(self, served):
        server, _ = served
        body = codec.encode_request("topk", tile_table(1), hw="b200", k=2)
        import http.client
        conn = http.client.HTTPConnection(*server.address)
        try:
            conn.request("POST", "/v1/argmin", body,
                         {"Content-Type": "application/x-repro-wire"})
            resp = conn.getresponse()
            data = resp.read()
            assert resp.status == 400
            with pytest.raises(codec.RemoteError, match="got a request"):
                codec.raise_if_error(data)
        finally:
            conn.close()

    def test_empty_table_argmin_is_400(self, served):
        _, client = served
        empty = tile_table(1)._slice(0, 0)
        with pytest.raises(codec.RemoteError, match="empty sweep"):
            client.argmin(empty, "b200")

    def test_unread_body_error_closes_connection(self, served):
        # 413/411/400-negative replies skip reading the body; the server
        # must drop the keep-alive connection or the unread bytes desync
        # the next request on the same socket
        server, client = served
        import http.client
        from repro.serve.server import MAX_BODY_BYTES
        conn = http.client.HTTPConnection(*server.address)
        try:
            conn.request(
                "POST", "/v1/argmin", b"x" * 64,
                {"Content-Type": "application/x-repro-wire",
                 "Content-Length": str(MAX_BODY_BYTES + 1)})
            resp = conn.getresponse()
            resp.read()
            assert resp.status == 413
            assert resp.will_close
            # same conn object: http.client reconnects after the close,
            # and the request must parse cleanly (no stale body bytes)
            body = codec.encode_request("argmin", tile_table(1),
                                        hw="b200")
            conn.request("POST", "/v1/argmin", body,
                         {"Content-Type": "application/x-repro-wire"})
            resp = conn.getresponse()
            data = resp.read()
            assert resp.status == 200
            codec.raise_if_error(data)
        finally:
            conn.close()
        assert client.health()["status"] == "ok"

    def test_negative_content_length_is_400(self, served):
        # a negative length must be rejected before rfile.read(-1) can
        # block the handler thread on the open keep-alive socket
        server, client = served
        import http.client
        conn = http.client.HTTPConnection(*server.address)
        try:
            conn.request("POST", "/v1/argmin", None,
                         {"Content-Type": "application/x-repro-wire",
                          "Content-Length": "-5"})
            resp = conn.getresponse()
            data = resp.read()
            assert resp.status == 400
            with pytest.raises(codec.RemoteError,
                               match="invalid Content-Length"):
                codec.raise_if_error(data)
        finally:
            conn.close()
        assert client.health()["status"] == "ok"   # server survived


class TestCoalescing:
    def test_concurrent_requests_fuse_and_stay_exact(self):
        # a long window makes the fusion deterministic
        with PredictionServer(port=0, coalesce_window_s=0.2) as server:
            server.start()
            client = PredictionClient(*server.address)
            parts = [WorkloadTable.tile_lattice(
                gemm_base(f"s{j}", 2048 + 256 * j), TILES[:9])
                for j in range(6)]
            ops = ["argmin", "topk", "pareto"] * 2
            results = [None] * 6

            def go(j):
                if ops[j] == "argmin":
                    results[j] = [client.argmin(parts[j], "b200")]
                elif ops[j] == "topk":
                    results[j] = client.topk(parts[j], "b200", 3)
                else:
                    results[j] = client.pareto(parts[j], "b200")

            threads = [threading.Thread(target=go, args=(j,))
                       for j in range(6)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for j in range(6):
                if ops[j] == "argmin":
                    ref = [sweep.argmin_table(parts[j], B200,
                                              engine=fresh_engine())]
                elif ops[j] == "topk":
                    ref = sweep.topk_table(parts[j], B200, 3,
                                           engine=fresh_engine())
                else:
                    ref = sweep.pareto_table(parts[j], B200,
                                             engine=fresh_engine())
                assert all(same_winner(a, b)
                           for a, b in zip(results[j], ref))
            st = server.stats()
            assert st["coalescer_coalesced_requests"] >= 2
            assert st["coalescer_fused_evaluations"] >= 1
            assert st["coalescer_fused_evaluations"] < 6
            client.close()

    def test_mixed_hardware_groups_never_fuse(self):
        with PredictionServer(port=0, coalesce_window_s=0.2) as server:
            server.start()
            client = PredictionClient(*server.address)
            table = tile_table(n_shapes=1)
            results = {}

            def go(hw_name):
                results[hw_name] = client.argmin(table, hw_name)

            threads = [threading.Thread(target=go, args=(n,))
                       for n in ("b200", "mi300a")]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for hw_name in ("b200", "mi300a"):
                ref = sweep.argmin_table(table, hardware.get(hw_name),
                                         engine=fresh_engine())
                assert same_winner(results[hw_name], ref)
            client.close()

    def test_coalescer_direct_exactness_per_window(self):
        """Unit-level: many windows fused into one table, each answered
        from its own row slice (no HTTP in the way)."""
        eng = sweep.SweepEngine(use_cache=False)
        co = Coalescer(eng, window_s=0.1)
        parts = [WorkloadTable.tile_lattice(
            gemm_base(f"u{j}", 2048 + 128 * j), TILES[:7])
            for j in range(5)]
        out = [None] * 5

        def go(j):
            out[j] = co.submit("argmin", parts[j], B200, None)

        threads = [threading.Thread(target=go, args=(j,))
                   for j in range(5)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        co.close()
        for j in range(5):
            ref = [sweep.argmin_table(parts[j], B200,
                                      engine=fresh_engine())]
            assert all(same_winner(a, b) for a, b in zip(out[j], ref))
        assert co.stats["coalesced_requests"] == 5
        assert co.stats["fused_evaluations"] == 1

    def test_oversized_groups_split(self):
        eng = sweep.SweepEngine(use_cache=False)
        co = Coalescer(eng, window_s=0.1, max_fused_rows=10)
        parts = [WorkloadTable.tile_lattice(gemm_base(f"o{j}"), TILES[:8])
                 for j in range(4)]
        out = [None] * 4

        def go(j):
            out[j] = co.submit("argmin", parts[j], B200, None)

        threads = [threading.Thread(target=go, args=(j,))
                   for j in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        co.close()
        for j in range(4):
            assert same_winner(
                out[j][0],
                sweep.argmin_table(parts[j], B200, engine=fresh_engine()))


class TestWorkerPoolReuse:
    def test_pool_reuse_bit_identical(self):
        spec = LatticeSpec.cartesian(
            gemm_base(), k_tiles=[8 + i for i in range(48)],
            num_ctas=[32 + 8 * i for i in range(48)])
        ref = sweep.argmin_stream(spec, B200)
        with parallel.WorkerPool(2, use_threads=True) as pool:
            for _ in range(3):
                assert same_winner(
                    sweep.argmin_stream(spec, B200, pool=pool,
                                        chunk_size=256), ref)

    @pytest.mark.skipif(not parallel.processes_available(),
                        reason="worker processes unavailable")
    def test_process_pool_reuse_and_shared_memory(self):
        table = tile_table(n_shapes=4)
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        with parallel.WorkerPool(2) as pool:
            for _ in range(2):
                got = sweep.argmin_stream(table, B200, pool=pool,
                                          chunk_size=64)
                assert same_winner(got, ref)

    def test_server_uses_pool_for_spec_routes(self):
        with PredictionServer(port=0, jobs=2, use_threads=True) as server:
            server.start()
            assert server.pool is not None
            client = PredictionClient(*server.address)
            spec = LatticeSpec.cartesian(
                gemm_base(), k_tiles=[8 + i for i in range(40)],
                num_ctas=[32 + 8 * i for i in range(40)])
            got = client.argmin(spec, "b200", chunk_size=256)
            assert same_winner(got, sweep.argmin_stream(spec, B200))
            client.close()


class TestSmokeFlagBugfix:
    def test_no_smoke_reaches_full_configs(self):
        from repro.launch.serve import build_parser
        ap = build_parser()
        assert ap.parse_args(["--arch", "x"]).smoke is True
        assert ap.parse_args(["--arch", "x", "--smoke"]).smoke is True
        # the bug: action="store_true", default=True made this unreachable
        assert ap.parse_args(["--arch", "x", "--no-smoke"]).smoke is False


class TestSpawnSafety:
    def test_pickle_strips_interned_hardware_token(self):
        hw = hardware.B200
        sweep.hardware_key(hw)
        assert "_sweep_content_token" in hw.__dict__
        out = pickle.loads(pickle.dumps(hw))
        assert "_sweep_content_token" not in out.__dict__
        assert out == hw
        # re-derivation in the same process lands on the same intern
        assert sweep.hardware_key(out) == sweep.hardware_key(hw)

    def test_spawn_worker_cannot_collide_on_stale_tokens(self, monkeypatch):
        """Pre-fix, a pickled HardwareParams carried the parent's (name,
        id) token; a spawn worker's fresh intern table hands the same id
        to different content, colliding cache keys across hardware."""
        parent_a = pickle.loads(pickle.dumps(hardware.B200))
        parent_b = pickle.loads(pickle.dumps(
            hardware.B200.with_updates(hbm_sustained_bw=1.0)))
        monkeypatch.setattr(sweep, "_HW_TOKENS", {})
        sweep.hardware_key(parent_a)          # parent interns A as id 0
        wire_a = pickle.dumps(parent_a)       # ships to the worker
        monkeypatch.setattr(sweep, "_HW_TOKENS", {})   # fresh worker
        child_b = sweep.hardware_key(parent_b)         # B interned first
        child_a = sweep.hardware_key(pickle.loads(wire_a))
        assert child_a != child_b

    def test_mp_context_never_forks_a_threaded_process(self):
        """Forking a multithreaded process can deadlock the child in a
        mutex another thread held at fork time; the serve front end is
        always multithreaded (HTTP handlers + coalescer), so its worker
        pools must come from an exec'd-clean start method."""
        stop = threading.Event()
        t = threading.Thread(target=stop.wait, daemon=True)
        t.start()
        try:
            ctx = parallel._mp_context()
            assert ctx.get_start_method() != "fork"
        finally:
            stop.set()

    def test_worker_pool_never_forks(self):
        """ProcessPoolExecutor starts workers lazily at first submit, so
        a long-lived WorkerPool constructed while single-threaded could
        otherwise fork AFTER the caller starts helper threads — it must
        refuse fork up front."""
        if not parallel.processes_available():
            pytest.skip("process pools unavailable in this sandbox")
        with parallel.WorkerPool(2) as pool:
            assert pool.is_processes
            method = pool.executor._mp_context.get_start_method()
            assert method != "fork"

    def test_bind_failure_leaks_no_coalescer_or_pool(self):
        """A port-in-use OSError from the constructor must not leave a
        coalescer thread (or pool workers) running with no handle."""
        def coalescer_threads():
            return [t for t in threading.enumerate()
                    if t.name == "serve-coalescer"]
        with PredictionServer(port=0) as taken:
            taken.start()
            before = len(coalescer_threads())
            with pytest.raises(OSError):
                PredictionServer(port=taken.address[1], jobs=2,
                                 use_threads=True)
            assert len(coalescer_threads()) == before

    def test_workload_nvec_cache_is_content_pure(self):
        w = gemm_base()
        _ = w._nvec                            # populate the lazy buffer
        out = pickle.loads(pickle.dumps(w))
        # _nvec is a pure function of the fields, so a pickled copy of the
        # buffer can never go stale — it must also still be correct
        assert out._nvec == w._nvec


@pytest.mark.slow
class TestSecondProcessEndToEnd:
    """The acceptance criterion: a real second process answers a >=10k-row
    table and a >=1M-row lattice bit-identically to in-process calls."""

    @pytest.fixture(scope="class")
    def remote(self):
        from repro.serve.subproc import (start_server_subprocess,
                                         stop_server_subprocess)
        proc, host, port = start_server_subprocess()
        try:
            client = PredictionClient(host, port, timeout=300.0)
            # wait for liveness
            deadline = time.time() + 30
            while True:
                try:
                    assert client.health()["status"] == "ok"
                    break
                except Exception:
                    if time.time() > deadline:
                        raise
                    time.sleep(0.1)
            yield client
            client.close()
        finally:
            stop_server_subprocess(proc)

    def test_10k_row_table_argmin_bit_identical(self, remote):
        table = tile_table(n_shapes=380)       # 380 * 27 = 10,260 rows
        assert len(table) >= 10_000
        got = remote.argmin(table, "b200")
        ref = sweep.argmin_table(table, B200, engine=fresh_engine())
        assert same_winner(got, ref)
        got_k = remote.topk(table, "b200", 10)
        ref_k = sweep.topk_table(table, B200, 10, engine=fresh_engine())
        assert all(same_winner(a, b) for a, b in zip(got_k, ref_k))

    def test_1m_row_lattice_argmin_bit_identical(self, remote):
        spec = LatticeSpec.cartesian(
            gemm_base("big", 8192),
            k_tiles=[8 + 4 * i for i in range(64)],
            num_ctas=[32 + 8 * i for i in range(64)],
            tma_participants=[1, 2, 4, 8] * 4,
            concurrent_kernels=[1, 2] * 8)
        assert spec.n_rows >= 1_000_000
        got = remote.argmin(spec, "b200")
        ref = sweep.argmin_stream(spec, B200)
        assert same_winner(got, ref)

    def test_mixed_precision_wire_table_hits_cache_cross_order(self, remote):
        """End-to-end replay of the vocab-canonicalization fix: the same
        semantic table sent with two vocab orders is one cache entry."""
        w1 = gemm_base("a")
        w2 = streaming_workload("b", 1e9, precision="fp32")
        ta = WorkloadTable.from_workloads([w1, w2])
        tb = WorkloadTable.from_workloads([w2, w1]).take(np.array([1, 0]))
        remote.clear_cache()
        remote.argmin(ta, "b200")
        hits0 = remote.cache_stats()["hits"]
        got = remote.argmin(tb, "b200")
        assert remote.cache_stats()["hits"] >= hits0 + len(tb)
        assert same_winner(got, sweep.argmin_table(tb, B200,
                                                   engine=fresh_engine()))


class TestHardwareLibraryEndpoints:
    def test_directory_lists_every_registry_entry(self, served):
        _, client = served
        d = client.hardware_list()
        assert d["count"] == len(d["hardware"]) == len(hardware.REGISTRY)
        assert d["hardware"]["b200"]["model_family"] == "blackwell"
        assert d["hardware"]["mi300a"]["num_sms"] == 304

    def test_get_entry_ships_audit_trail_and_exact_params(self, served):
        _, client = served
        entry = client.hardware_get("b200")
        assert entry.params == hardware.get("b200")
        assert entry.provenance          # file-backed: provenance travels
        assert entry.source
        with pytest.raises(codec.RemoteError, match="unknown hardware"):
            client.hardware_get("gtx1080")

    def test_register_is_idempotent_and_collision_safe(self, served):
        _, client = served
        p = B200.with_updates(name="b200_test_reg", hbm_sustained_bw=5e12)
        try:
            assert client.hardware_register(p) == {
                "registered": "b200_test_reg", "replaced": False}
            # identical payload replays cleanly (the client retry contract)
            assert client.hardware_register(p)["registered"] == \
                "b200_test_reg"
            # a *different* payload for the taken name is a 400
            with pytest.raises(codec.RemoteError,
                               match="already registered"):
                client.hardware_register(
                    p.with_updates(hbm_sustained_bw=6e12))
            out = client.hardware_register(
                p.with_updates(hbm_sustained_bw=6e12), overwrite=True)
            assert out == {"registered": "b200_test_reg", "replaced": True}
            assert hardware.get("b200_test_reg").hbm_sustained_bw == 6e12
            # the registered entry prices like any shipped one
            table = tile_table(n_shapes=1)
            got = client.argmin(table, "b200_test_reg")
            ref = sweep.argmin_table(table, hardware.get("b200_test_reg"),
                                     engine=fresh_engine())
            assert same_winner(got, ref)
        finally:
            del hardware.REGISTRY["b200_test_reg"]

    def test_register_rejects_schema_violations(self, served):
        server, client = served
        from repro.core import hwlib
        doc = hwlib.HardwareEntry(params=B200).to_doc()
        doc["params"]["model_family"] = "volta"
        import http.client
        conn = http.client.HTTPConnection(*server.address)
        try:
            body = codec._pack(codec.MSG_HARDWARE, [
                (b"meta", codec._json_bytes({"entry": doc}))])
            conn.request("POST", "/v1/hardware", body,
                         {"Content-Type": "application/x-repro-wire"})
            resp = conn.getresponse()
            data = resp.read()
            assert resp.status == 400
            with pytest.raises(codec.RemoteError,
                               match="unknown model_family"):
                codec.raise_if_error(data)
        finally:
            conn.close()
        assert client.health()["status"] == "ok"


def synthetic_suite(hw, n_kernels=8, scale=1.17):
    """Measured-times suite fabricated as (server prediction x scale), so
    the fitted multipliers are known and deterministic."""
    eng = sweep.SweepEngine(use_cache=False)
    ws, meas = [], []
    for i in range(n_kernels):
        n = 512 + 256 * i
        w = gemm_workload(f"cal{i}_{n}", n, n, n, precision="fp16")
        ws.append(w)
        meas.append(eng.predict(w, hw).total * (scale + 0.01 * i))
    from repro.core.microbench import MeasuredSuite
    return MeasuredSuite(name="synthetic", workloads=ws, measured_s=meas)


class TestCalibrationOverTheWire:
    def test_served_fit_matches_in_process_bit_exactly(self, served):
        from repro.core import calibrate
        server, client = served
        suite = synthetic_suite(B200)
        cal, report = client.calibrate(suite, "b200", mode="class",
                                       holdout_fraction=0.3, seed=3,
                                       register_as="fit_exact")
        ref_cal, ref_report = calibrate.fit_with_holdout(
            suite.workloads, suite.measured_s,
            lambda w: server.engine.predict(w, B200),
            mode="class", holdout_fraction=0.3, seed=3)
        assert cal.to_dict() == ref_cal.to_dict()
        assert report == ref_report
        assert client.health()["n_calibrations"] >= 1

    def test_calibrated_sweeps_bit_identical_to_in_process(self, served):
        server, client = served
        suite = synthetic_suite(B200)
        # class mode: the fitted "compute" multiplier applies to *other*
        # gemm kernels too (a per-case fit only matches by kernel name)
        cal, _ = client.calibrate(suite, "b200", mode="class",
                                  register_as="fit_sweep")
        table = tile_table(n_shapes=2)
        for op, kw in (("argmin", {}), ("topk", {"k": 5}),
                       ("pareto", {})):
            got = getattr(client, op)(
                table, "b200", calibration="fit_sweep",
                **({"k": 5} if op == "topk" else {}))
            if op == "argmin":
                got = [got]
            if op == "argmin":
                ref = [sweep.argmin_table(table, B200, calibration=cal,
                                          engine=fresh_engine())]
            elif op == "topk":
                ref = sweep.topk_table(table, B200, 5, calibration=cal,
                                       engine=fresh_engine())
            else:
                ref = sweep.pareto_table(table, B200, calibration=cal,
                                         engine=fresh_engine())
            assert all(same_winner(a, b) for a, b in zip(got, ref)), op
        tots = client.predict_totals(table, "b200",
                                     calibration="fit_sweep")
        ref_tots = fresh_engine().predict_table(
            table, B200, calibration=cal).totals
        assert np.array_equal(tots, ref_tots)
        # calibrated != raw (the multipliers actually applied)
        assert not np.array_equal(tots, client.predict_totals(table,
                                                              "b200"))

    def test_calibrated_spec_stream_routes(self, served):
        _, client = served
        suite = synthetic_suite(B200)
        cal, _ = client.calibrate(suite, "b200", register_as="fit_spec")
        spec = LatticeSpec.cartesian(
            gemm_base(), k_tiles=[8 + i for i in range(16)],
            num_ctas=[32 + 8 * i for i in range(16)])
        got = client.argmin(spec, "b200", calibration="fit_spec")
        ref = sweep.argmin_stream(spec, B200, calibration=cal)
        assert same_winner(got, ref)
        tots = client.predict_totals(spec, "b200", calibration="fit_spec")
        assert np.array_equal(tots, sweep.predict_totals_stream(
            spec, B200, calibration=cal))

    def test_unknown_calibration_name_is_400(self, served):
        _, client = served
        with pytest.raises(codec.RemoteError,
                           match="unknown calibration 'nope'"):
            client.argmin(tile_table(1), "b200", calibration="nope")

    def test_calibrate_retry_is_idempotent(self, served):
        server, client = served
        suite = synthetic_suite(B200)
        cal1, rep1 = client.calibrate(suite, "b200",
                                      register_as="fit_retry")
        stored1 = server.calibrations["fit_retry"].cal.to_dict()
        cal2, rep2 = client.calibrate(suite, "b200",
                                      register_as="fit_retry")
        assert cal1.to_dict() == cal2.to_dict() and rep1 == rep2
        assert server.calibrations["fit_retry"].cal.to_dict() == stored1

    def test_skipped_kernels_disclosed_over_the_wire(self, served):
        """A suite entry with an unusable measurement (0.0 s — a timer
        failure) must come back with that kernel named in the
        calibration's skip list rather than silently poisoning the fit."""
        from repro.core.microbench import MeasuredSuite
        _, client = served
        good = synthetic_suite(B200, n_kernels=6)
        dead = gemm_workload("empty_kernel", 256, 256, 256,
                             precision="fp16")
        suite = MeasuredSuite(
            name="with_dead", workloads=list(good.workloads) + [dead],
            measured_s=list(good.measured_s) + [0.0])
        cal, report = client.calibrate(suite, "b200", mode="class",
                                       holdout_fraction=0.0, seed=0)
        assert "empty_kernel" in cal.skipped
        assert report["n_skipped"] == float(len(cal.skipped))
        assert cal.disclose()["skipped"] == cal.skipped

    def test_raw_and_calibrated_never_fuse(self):
        """Coalescer contract: same table+hardware with and without a
        calibration must land in different groups, and each answer stays
        bit-identical to its own in-process counterpart."""
        from repro.core.calibrate import Calibration
        from repro.serve.server import _NamedCalibration
        eng = sweep.SweepEngine(use_cache=False)
        co = Coalescer(eng, window_s=0.2)
        cal = Calibration(per_class={"compute": 3.0}, global_scale=2.0)
        named = _NamedCalibration("x3", cal)
        table = tile_table(n_shapes=1)
        out = {}

        def go(key, calibration):
            out[key] = co.submit("argmin", table, B200, None,
                                 calibration=calibration)

        threads = [threading.Thread(target=go, args=("raw", None)),
                   threading.Thread(target=go, args=("cal", named))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        co.close()
        assert same_winner(out["raw"][0], sweep.argmin_table(
            table, B200, engine=fresh_engine()))
        assert same_winner(out["cal"][0], sweep.argmin_table(
            table, B200, calibration=cal, engine=fresh_engine()))
        assert out["raw"][0].total != out["cal"][0].total
        # two groups -> no fused cross-group evaluation of the pair
        assert co.stats["fused_evaluations"] == 0

    def test_same_named_calibration_may_fuse_and_stays_exact(self):
        from repro.core.calibrate import Calibration
        from repro.serve.server import _NamedCalibration
        eng = sweep.SweepEngine(use_cache=False)
        co = Coalescer(eng, window_s=0.2)
        named = _NamedCalibration(
            "shared", Calibration(global_scale=1.5))
        parts = [WorkloadTable.tile_lattice(
            gemm_base(f"cf{j}", 2048 + 128 * j), TILES[:7])
            for j in range(4)]
        out = [None] * 4

        def go(j):
            out[j] = co.submit("argmin", parts[j], B200, None,
                               calibration=named)

        threads = [threading.Thread(target=go, args=(j,))
                   for j in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        co.close()
        for j in range(4):
            assert same_winner(out[j][0], sweep.argmin_table(
                parts[j], B200, calibration=named.cal,
                engine=fresh_engine()))
        assert co.stats["fused_evaluations"] == 1

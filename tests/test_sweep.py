"""SweepEngine parity + behavior tests.

The engine's core invariant: ``SweepEngine.predict_batch([w], hw)[0]`` is
bit-identical to the pre-refactor scalar ``predict(w, hw)`` (which is the
per-architecture model function) for every route — stage, wavefront, tpu,
generic, roofline — across all five registered hardware targets, and the
vectorized row backends match element-for-element on real batches
(including the detail dicts)."""
import random

import pytest

from repro.core import autotune, blackwell, calibrate, cdna3, generic, \
    hardware, predict as predict_mod, roofline, sweep, tpu
from repro.core.workload import TileConfig, Workload, gemm_workload, \
    streaming_workload, tb_from_row

HW_ALL = [hardware.B200, hardware.H200, hardware.MI300A, hardware.MI250X,
          hardware.TPU_V5E]

SCALAR = {"stage": blackwell.predict, "wavefront": cdna3.predict,
          "tpu": tpu.predict, "generic": generic.predict,
          "roofline": roofline.predict}


def routes_for(hw):
    routes = ["generic", "roofline"]
    if hw.model_family in ("blackwell", "tpu"):
        routes.append("stage")
    if hw.model_family == "cdna":
        routes.append("wavefront")
    if hw.model_family == "tpu":
        routes.append("tpu")
    return routes


def mixed_workloads(hw, n=80, seed=1):
    """GEMM / streaming / tiled / plain workloads with per-target-valid
    precisions (exotic precisions raise identically on both paths)."""
    rng = random.Random(seed)
    vec_precs = ["fp32"] if hw.model_family == "tpu" else ["fp32", "fp64"]
    mat_precs = ["fp16", "bf16", "fp8"]
    out = []
    for i in range(n):
        kind = rng.choice(["gemm", "stream", "tiled", "plain"])
        if kind == "gemm":
            m, nn, k = (rng.choice([100, 512, 2048, 8192]) for _ in range(3))
            out.append(gemm_workload(
                f"g{i}", m, nn, k, precision=rng.choice(mat_precs),
                tile=TileConfig(rng.choice([64, 128, 256]),
                                rng.choice([64, 128, 256]),
                                rng.choice([16, 32, 64]))))
        elif kind == "stream":
            out.append(streaming_workload(
                f"s{i}", rng.uniform(1e4, 1e12),
                precision=rng.choice(vec_precs),
                irregular=rng.random() < 0.3))
        elif kind == "tiled":
            out.append(Workload(
                name=f"t{i}", wclass="compute",
                flops=rng.uniform(1e6, 1e15), bytes=rng.uniform(1e4, 1e12),
                precision=rng.choice(mat_precs), matrix=True,
                tile=TileConfig(128, 128, 64),
                k_tiles=rng.randint(1, 256), num_ctas=rng.randint(0, 5000),
                working_set_bytes=rng.uniform(0, 1e9),
                compressed_bytes=rng.choice([0.0, 1e8]),
                compression_ratio=2.0,
                tma_participants=rng.choice([1, 2, 4]),
                concurrent_kernels=rng.choice([1, 2]),
                num_devices=rng.choice([1, 4])))
        else:
            out.append(Workload(
                name=f"p{i}",
                wclass=rng.choice(["memory", "compute", "balanced",
                                   "stencil"]),
                flops=rng.uniform(0, 1e14), bytes=rng.uniform(1e3, 1e12),
                precision=rng.choice(vec_precs), matrix=False,
                working_set_bytes=rng.uniform(0, 1e10),
                vgpr_per_workitem=rng.choice([32, 64, 128, 256]),
                hit_rates={"llc": 0.7} if rng.random() < 0.2 else {},
                num_loads=rng.choice([0.0, 1e6]),
                irregular=rng.random() < 0.2))
    return out


def assert_identical(got, expected):
    assert got == expected, (got, expected)
    assert got.detail == expected.detail, (got.detail, expected.detail)


class TestBatchOfOneParity:
    @pytest.mark.parametrize("hw", HW_ALL, ids=lambda h: h.name)
    def test_every_route_bit_identical(self, hw):
        for route in routes_for(hw):
            for w in mixed_workloads(hw, n=12, seed=7):
                got = sweep.SweepEngine().predict_batch(
                    [w], hw, model=route)[0]
                assert_identical(got, SCALAR[route](w, hw))

    @pytest.mark.parametrize("hw", HW_ALL, ids=lambda h: h.name)
    def test_default_route_matches_predict(self, hw):
        w = gemm_workload("g", 4096, 4096, 4096, precision="fp16")
        assert_identical(sweep.SweepEngine().predict_batch([w], hw)[0],
                         predict_mod.predict(w, hw))


class TestVectorizedParity:
    """Real batches exercise the vectorized row backends (above the
    scalar-fallback cutoff) against the scalar model functions."""

    @pytest.mark.parametrize("hw", HW_ALL, ids=lambda h: h.name)
    def test_batch_matches_scalar_elementwise(self, hw):
        for route in routes_for(hw):
            ws = mixed_workloads(hw, n=80, seed=3)
            rows = sweep._rows_fn(route)(ws, hw)
            assert len(rows) == len(ws)
            for w, row in zip(ws, rows):
                assert_identical(tb_from_row(row), SCALAR[route](w, hw))

    def test_engine_large_batch_uses_vectorized_path(self):
        ws = mixed_workloads(hardware.B200, n=64, seed=5)
        got = sweep.SweepEngine().predict_batch(ws, hardware.B200)
        for w, g in zip(ws, got):
            assert_identical(g, blackwell.predict(w, hardware.B200))


class TestEngineBehavior:
    def test_unknown_route_raises(self):
        w = streaming_workload("s", 1e9)
        with pytest.raises(ValueError, match="unknown model route"):
            sweep.SweepEngine().predict_batch([w], hardware.B200,
                                              model="nope")

    def test_misrouted_hw_raises(self):
        w = streaming_workload("s", 1e9)
        with pytest.raises(ValueError, match="mis-routed"):
            sweep.SweepEngine().predict_batch(
                [w] * 32, hardware.MI300A, model="stage")

    def test_cache_hits_are_identical_and_counted(self):
        eng = sweep.SweepEngine()
        ws = mixed_workloads(hardware.MI300A, n=40, seed=9)
        first = list(eng.predict_batch(ws, hardware.MI300A))
        assert eng.cache_stats()["misses"] == 40
        second = list(eng.predict_batch(ws, hardware.MI300A))
        assert eng.cache_stats()["hits"] == 40
        for a, b in zip(first, second):
            assert_identical(a, b)

    def test_cache_entries_immune_to_caller_mutation(self):
        eng = sweep.SweepEngine()
        w = streaming_workload("s", 1e9)
        a = eng.predict(w, hardware.B200)
        a.detail["poison"] = 1.0
        b = eng.predict(w, hardware.B200)
        assert "poison" not in b.detail

    def test_content_keyed_not_name_keyed(self):
        """Same characterization under two names shares one entry; a
        re-registered parameter file with changed content must NOT serve
        stale results."""
        eng = sweep.SweepEngine()
        w1 = streaming_workload("a", 1e9)
        w2 = streaming_workload("b", 1e9)
        eng.predict(w1, hardware.B200)
        eng.predict(w2, hardware.B200)
        assert eng.cache_stats()["hits"] == 1
        hw2 = hardware.B200.with_updates(hbm_sustained_bw=1e12)
        t1 = eng.predict(w1, hardware.B200).total
        t2 = eng.predict(w1, hw2).total
        assert t1 != t2

    def test_calibration_applied_after_cache(self):
        eng = sweep.SweepEngine()
        w = gemm_workload("g", 2048, 2048, 2048, precision="fp16")
        cal = calibrate.Calibration(per_case={"g": 2.0})
        plain = eng.predict(w, hardware.B200)
        scaled = eng.predict(w, hardware.B200, calibration=cal)
        assert scaled.total == plain.total * 2.0
        assert scaled.detail["m_case"] == 2.0
        again = eng.predict(w, hardware.B200)
        assert "m_case" not in again.detail
        assert again.total == plain.total

    def test_batchresult_sequence_api(self):
        eng = sweep.SweepEngine()
        ws = mixed_workloads(hardware.B200, n=20, seed=11)
        res = eng.predict_batch(ws, hardware.B200)
        assert len(res) == 20
        assert res[-1] == list(res)[-1]
        totals = res.totals
        assert len(totals) == 20
        assert totals[res.argmin()] == min(totals)
        for t, tb in zip(totals, res):
            assert t == tb.total

    def test_scalar_predict_delegates_to_engine(self):
        eng = sweep.default_engine()
        before = eng.cache_stats()["misses"] + eng.cache_stats()["hits"]
        w = streaming_workload("delegate_check", 12345.0)
        predict_mod.predict(w, hardware.H200)
        after = eng.cache_stats()["misses"] + eng.cache_stats()["hits"]
        assert after == before + 1


class TestAutotuneBatched:
    def test_select_tile_matches_scalar_argmin(self):
        base = gemm_workload("sel", 4096, 4096, 4096, precision="fp16")
        tiles = [TileConfig(s, s, 32) for s in (64, 128, 256)] * 8
        best, costs = autotune.select_tile(base, hardware.B200, tiles)
        from repro.core.cdna3 import _retile
        scalar = {f"{t.bm}x{t.bn}x{t.bk}":
                  blackwell.predict(_retile(base, t), hardware.B200).total
                  for t in tiles}
        assert costs == scalar
        assert costs[f"{best.bm}x{best.bn}x{best.bk}"] == min(costs.values())

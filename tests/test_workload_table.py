"""WorkloadTable columnar-sweep tests.

Covers: constructor equivalence (from_workloads / tile_lattice / cartesian
vs the Workload-object path), fused reductions (argmin/topk/pareto) parity
with a sorted full materialization on randomized sweeps across all five
routes including ties, the two-tier memo cache (whole-table and whole-batch
replay, LRU bound), thread safety under concurrent predict_batch, the lazy
``_nvec`` memoization, and columnar enumerate_plans parity."""
import random
import threading

import numpy as np
import pytest

from repro.core import autotune, collectives, hardware, sweep
from repro.core.cdna3 import _retile
from repro.core.workload import NV_BYTES, NV_COLS, NV_WS_OR_BYTES, \
    TileConfig, Workload, WorkloadTable, gemm_workload, nvec_matrix, \
    streaming_workload
from tests.test_sweep import HW_ALL, SCALAR, assert_identical, \
    mixed_workloads, routes_for


def fresh_engine():
    return sweep.SweepEngine(use_cache=False)


class TestConstructors:
    def test_from_workloads_matches_nvec_matrix(self):
        ws = mixed_workloads(hardware.B200, n=40, seed=2)
        t = WorkloadTable.from_workloads(ws)
        assert t.cols.shape == (40, NV_COLS)
        assert np.array_equal(t.cols, nvec_matrix(ws))
        assert [t.name(i) for i in range(len(t))] == [w.name for w in ws]

    def test_workload_roundtrip(self):
        ws = mixed_workloads(hardware.MI300A, n=30, seed=3)
        t = WorkloadTable.from_workloads(ws)
        for i, w in enumerate(ws):
            assert t.workload(i) == w

    def test_tile_lattice_matches_retile(self):
        base = gemm_workload("g", 4000, 4096, 4096, precision="fp16")
        tiles = [TileConfig(bm, bn, bk) for bm in (64, 128, 512)
                 for bn in (128, 256) for bk in (16, 64)]
        t = WorkloadTable.tile_lattice(base, tiles)
        assert np.array_equal(
            t.cols, nvec_matrix([_retile(base, c) for c in tiles]))

    def test_tile_lattice_gemmless_base(self):
        base = streaming_workload("s", 1e9)
        tiles = [TileConfig(64, 64, 16), TileConfig(128, 128, 32)]
        t = WorkloadTable.tile_lattice(base, tiles)
        assert np.array_equal(
            t.cols, nvec_matrix([base.replace(tile=c) for c in tiles]))

    def test_cartesian_grid(self):
        base = streaming_workload("s", 1e9)
        t = WorkloadTable.cartesian(
            base, bytes=[1e6, 1e9, 1e12], precision=["fp32", "fp64"])
        assert len(t) == 6
        ref = [base.replace(bytes=b, flops=base.flops, precision=p)
               for b in (1e6, 1e9, 1e12) for p in ("fp32", "fp64")]
        got_bytes = t.cols[:, NV_BYTES].tolist()
        assert got_bytes == [w.bytes for w in ref]
        assert [t.precision_vocab[c] for c in t.precision_codes] \
            == [w.precision for w in ref]

    def test_cartesian_ws_or_bytes_recomputed(self):
        # working_set_bytes == 0 must fall back to bytes, mirroring the
        # `working_set_bytes or bytes` packing rule
        base = Workload(name="p", wclass="memory", flops=0.0, bytes=5.0,
                        working_set_bytes=0.0)
        t = WorkloadTable.cartesian(base, bytes=[7.0, 11.0])
        assert t.cols[:, NV_WS_OR_BYTES].tolist() == [7.0, 11.0]

    def test_cartesian_rejects_unknown_field(self):
        with pytest.raises(ValueError, match="cannot sweep field"):
            WorkloadTable.cartesian(streaming_workload("s", 1e9),
                                    gemm=[None])

    def test_concat_merges_vocabs(self):
        a = WorkloadTable.from_workloads(
            [streaming_workload("a", 1e9, precision="fp64")])
        b = WorkloadTable.from_workloads(
            [streaming_workload("b", 1e9, precision="fp32"),
             streaming_workload("c", 1e9, precision="fp64")])
        t = WorkloadTable.concat([a, b])
        assert len(t) == 3
        assert [t.precision_vocab[c] for c in t.precision_codes] \
            == ["fp64", "fp32", "fp64"]
        assert [t.name(i) for i in range(3)] == ["a", "b", "c"]

    def test_lazy_nvec_memoized(self):
        w = streaming_workload("lazy", 1e9)
        assert "_nvec_buf" not in w.__dict__
        first = w._nvec
        assert "_nvec_buf" in w.__dict__
        assert w._nvec is first                 # memoized, not repacked
        assert w.replace(bytes=2e9)._nvec != first


class TestPredictTableParity:
    @pytest.mark.parametrize("hw", HW_ALL, ids=lambda h: h.name)
    def test_table_matches_batch_every_route(self, hw):
        ws = mixed_workloads(hw, n=60, seed=11)
        t = WorkloadTable.from_workloads(ws)
        for route in routes_for(hw):
            res = fresh_engine().predict_table(t, hw, model=route)
            assert np.array_equal(
                res.totals,
                fresh_engine().predict_batch(ws, hw, model=route).totals)
            # materialized rows equal the scalar model, detail included
            for i in (0, len(ws) // 2, len(ws) - 1):
                assert_identical(res[i], SCALAR[route](ws[i], hw))

    def test_cdna3_exotic_rows_fall_back_per_row(self):
        hw = hardware.MI300A
        ws = mixed_workloads(hw, n=50, seed=13)
        assert any(w.hit_rates or w.num_loads > 0 for w in ws)
        t = WorkloadTable.from_workloads(ws)
        res = fresh_engine().predict_table(t, hw, model="wavefront")
        for i, w in enumerate(ws):
            assert_identical(res[i], SCALAR["wavefront"](w, hw))

    def test_misrouted_table_raises(self):
        t = WorkloadTable.from_workloads([streaming_workload("s", 1e9)] * 4)
        with pytest.raises(ValueError, match="mis-routed"):
            fresh_engine().predict_table(t, hardware.MI300A, model="stage")

    def test_calibration_applied_like_batch(self):
        from repro.core import calibrate
        hw = hardware.B200
        ws = mixed_workloads(hw, n=24, seed=17)
        cal = calibrate.Calibration(per_case={ws[3].name: 2.5},
                                    per_class={"memory": 1.5},
                                    global_scale=0.5)
        t = WorkloadTable.from_workloads(ws)
        res_t = fresh_engine().predict_table(t, hw, calibration=cal)
        res_b = fresh_engine().predict_batch(ws, hw, calibration=cal)
        assert np.array_equal(res_t.totals, res_b.totals)
        for i in range(len(ws)):
            assert_identical(res_t[i], res_b[i])


class TestFusedReductions:
    @pytest.mark.parametrize("hw", HW_ALL, ids=lambda h: h.name)
    def test_topk_parity_with_sorted_materialization(self, hw):
        rng = random.Random(23)
        ws = mixed_workloads(hw, n=40, seed=23)
        ws = ws + [ws[i] for i in (rng.randrange(40),) * 3]  # forced ties
        t = WorkloadTable.from_workloads(ws)
        for route in routes_for(hw):
            full = list(fresh_engine().predict_batch(ws, hw, model=route))
            order = sorted(range(len(ws)), key=lambda i: full[i].total)
            k = 7
            got = sweep.topk_table(t, hw, k, model=route,
                                   engine=fresh_engine())
            assert [w.index for w in got] == order[:k]
            for w in got:
                assert_identical(w.breakdown, full[w.index])
            win = sweep.argmin_table(t, hw, model=route,
                                     engine=fresh_engine())
            assert win.index == order[0]
            assert_identical(win.breakdown, full[order[0]])

    def test_topk_tie_order_is_stable_by_index(self):
        w = gemm_workload("g", 2048, 2048, 2048, precision="fp16")
        t = WorkloadTable.from_workloads([w] * 5)
        got = sweep.topk_table(t, hardware.B200, 3, engine=fresh_engine())
        assert [x.index for x in got] == [0, 1, 2]

    def test_pareto_matches_bruteforce(self):
        hw = hardware.B200
        ws = mixed_workloads(hw, n=50, seed=29)
        t = WorkloadTable.from_workloads(ws)
        res = fresh_engine().predict_table(t, hw)
        pts = np.stack([res.field_totals("compute"),
                        res.field_totals("memory")], axis=1)

        def dominated(i):
            return any((pts[j] <= pts[i]).all() and (pts[j] < pts[i]).any()
                       for j in range(len(ws)) if j != i)

        expect = sorted((i for i in range(len(ws)) if not dominated(i)),
                        key=lambda i: (pts[i, 0], i))
        got = sweep.pareto_table(t, hw, engine=fresh_engine())
        assert [w.index for w in got] == expect

    def test_pareto_single_objective_is_argmin_set(self):
        hw = hardware.B200
        ws = mixed_workloads(hw, n=30, seed=31)
        t = WorkloadTable.from_workloads(ws)
        got = sweep.pareto_table(t, hw, objectives=("total",),
                                 engine=fresh_engine())
        totals = fresh_engine().predict_table(t, hw).totals
        assert all(w.total == totals.min() for w in got)


class TestTwoTierCache:
    def test_whole_table_replay_hits(self):
        eng = sweep.SweepEngine()
        ws = mixed_workloads(hardware.B200, n=30, seed=37)
        t = WorkloadTable.from_workloads(ws)
        first = eng.predict_table(t, hardware.B200)
        assert eng.cache_stats()["misses"] == 30
        again = eng.predict_table(t, hardware.B200)
        assert eng.cache_stats()["hits"] == 30
        assert eng.cache_stats()["table_entries"] == 1
        assert np.array_equal(first.totals, again.totals)
        # content-keyed: an equal-content table built separately also hits
        t2 = WorkloadTable.from_workloads(ws)
        eng.predict_table(t2, hardware.B200)
        assert eng.cache_stats()["hits"] == 60

    def test_whole_batch_replay_short_circuits(self):
        eng = sweep.SweepEngine()
        ws = mixed_workloads(hardware.B200, n=40, seed=41)
        first = eng.predict_batch(ws, hardware.B200)
        assert eng.cache_stats()["batch_entries"] == 1
        again = eng.predict_batch(ws, hardware.B200)
        assert eng.cache_stats()["hits"] == 40
        assert again._rows is first._rows      # tier-1: same rows object
        for a, b in zip(first, again):
            assert_identical(a, b)

    def test_table_totals_immune_to_caller_mutation(self):
        # uniform-route table: column reads hand out the cached arrays,
        # which are frozen — in-place edits raise instead of poisoning
        eng = sweep.SweepEngine()
        ws = [gemm_workload(f"g{i}", 2048 + 128 * i, 2048, 2048,
                            precision="fp16") for i in range(8)]
        t = WorkloadTable.from_workloads(ws)
        res = eng.predict_table(t, hardware.B200)
        before = res.totals.copy()
        with pytest.raises(ValueError):
            res.totals *= 1e3
        assert np.array_equal(eng.predict_table(t, hardware.B200).totals,
                              before)
        # mixed-route (segmented) results assemble fresh arrays per read;
        # mutating the returned array must not reach the cache either
        t2 = WorkloadTable.from_workloads(
            mixed_workloads(hardware.B200, n=20, seed=43))
        b2 = eng.predict_table(t2, hardware.B200).totals.copy()
        tot = eng.predict_table(t2, hardware.B200).totals
        try:
            tot *= 1e3
        except ValueError:
            pass
        assert np.array_equal(eng.predict_table(t2, hardware.B200).totals,
                              b2)

    def test_table_cache_lru_bounded(self):
        eng = sweep.SweepEngine(max_table_entries=2)
        for nbytes in (1e6, 2e6, 3e6, 4e6):
            t = WorkloadTable.from_workloads(
                [streaming_workload("s", nbytes)] * 4)
            eng.predict_table(t, hardware.B200)
        assert eng.cache_stats()["table_entries"] == 2

    def test_row_cache_lru_keeps_recent(self):
        eng = sweep.SweepEngine(max_entries=4)
        recent = streaming_workload("r", 123.0)
        eng.predict(recent, hardware.B200)
        for i in range(8):
            eng.predict(recent, hardware.B200)     # refresh recency
            eng.predict(streaming_workload("x", 1e3 + i), hardware.B200)
        assert len(eng._cache) <= 4
        h0 = eng.cache_stats()["hits"]
        eng.predict(recent, hardware.B200)
        assert eng.cache_stats()["hits"] == h0 + 1  # survived eviction

    def test_thread_hammer_identical_results_bounded_cache(self):
        bound = 500
        eng = sweep.SweepEngine(max_entries=bound)
        hw = hardware.B200
        batches = [mixed_workloads(hw, n=40, seed=s) for s in range(6)]
        expect = [fresh_engine().predict_batch(ws, hw).totals
                  for ws in batches]
        errors = []

        def hammer(tid):
            rng = random.Random(tid)
            try:
                for _ in range(30):
                    j = rng.randrange(len(batches))
                    got = eng.predict_batch(batches[j], hw).totals
                    if not np.array_equal(got, expect[j]):
                        errors.append((tid, j))
            except Exception as e:               # pragma: no cover
                errors.append((tid, repr(e)))

        threads = [threading.Thread(target=hammer, args=(i,))
                   for i in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors
        assert len(eng._cache) <= bound
        stats = eng.cache_stats()
        assert stats["hits"] + stats["misses"] == 8 * 30 * 40


class TestAutotunePlans:
    def test_enumerate_plans_matches_price_train_step(self):
        mesh = collectives.MeshSpec(axes=(("data", 8), ("model", 4)))
        plans = [autotune.PlanCandidate(name=f"p{i}", mesh=mesh, tp_degree=4,
                                        microbatches=m, remat=r,
                                        compressed_grads=c)
                 for i, (m, r, c) in enumerate(
                     [(1, "none", False), (8, "full", True),
                      (4, "block", False)])]
        kw = dict(model_flops=1e18, param_bytes=2e11,
                  activation_bytes=5e12)
        costs = autotune.enumerate_plans(
            plans, opt_state_bytes=4e11, activation_peak_bytes=1e12, **kw)
        for plan, c in zip(plans, costs):
            ref = autotune.price_train_step(plan, **kw)
            assert c.total_s == ref.total_s
            assert c.compute_s == ref.compute_s
            assert c.memory_s == ref.memory_s
            assert c.collective_s == ref.collective_s
            feasible = autotune.hbm_fits(
                plan, param_bytes=2e11, opt_state_bytes=4e11,
                activation_peak_bytes=1e12)
            assert c.detail["feasible"] == (1.0 if feasible else 0.0)

    def test_enumerate_plans_per_plan_opt_state_bytes(self):
        mesh = collectives.MeshSpec(axes=(("data", 4), ("model", 1)))
        plans = [autotune.PlanCandidate(name=f"p{i}", mesh=mesh, tp_degree=1)
                 for i in range(2)]
        kw = dict(model_flops=1e15, param_bytes=1e10,
                  activation_bytes=1e10, activation_peak_bytes=0.0)
        lo, hi = autotune.enumerate_plans(
            plans, opt_state_bytes=[1e9, 1e15], **kw)
        assert lo.detail["feasible"] == 1.0
        assert hi.detail["feasible"] == 0.0
        with pytest.raises(ValueError, match="opt_state_bytes"):
            autotune.enumerate_plans(plans, opt_state_bytes=[1e9], **kw)

    def test_select_tile_table_path_matches_scalar(self):
        from repro.core import blackwell
        base = gemm_workload("sel", 4096, 4096, 4096, precision="fp16")
        tiles = [TileConfig(s, s, 32) for s in (64, 128, 256)]
        best, costs = autotune.select_tile(base, hardware.B200, tiles,
                                           engine=fresh_engine())
        scalar = {f"{t.bm}x{t.bn}x{t.bk}":
                  blackwell.predict(_retile(base, t), hardware.B200).total
                  for t in tiles}
        assert costs == scalar
        assert costs[f"{best.bm}x{best.bn}x{best.bk}"] == min(costs.values())

"""Declarative hardware library: schema, round-trips, goldens, registry.

The load-bearing guarantee: moving the six presets from Python
constructors into ``core/hwdata/*.json`` changed *nothing* numerically.
The golden argmin tests pin the exact (winner index, total seconds) each
preset produced from the in-code constructors immediately before the
refactor — JSON floats round-trip via Python's shortest repr, so the
loaded parameters must predict bit-identically.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core import calibrate, hardware, hwlib, sweep
from repro.core.workload import TileConfig, WorkloadTable, gemm_workload

# (winner row index, winner total seconds) of a 27-tile 4096^3 GEMM
# lattice argmin per preset, captured from the pre-refactor in-code
# constructors.  Exact equality: the data files ARE those constructors.
GOLDEN_ARGMIN = {
    ("b200", "fp16"): (26, 0.0001204135781326555),
    ("b200", "fp32"): (26, 0.0001466261009694249),
    ("h200", "fp16"): (17, 0.0002385608426607762),
    ("h200", "fp32"): (17, 0.0003224656335505636),
    ("mi300a", "fp16"): (0, 0.000269445995178178),
    ("mi300a", "fp32"): (0, 0.0013375075941180678),
    ("mi250x", "fp16"): (0, 0.0005003156677213033),
    ("mi250x", "fp32"): (0, 0.0018441494995665713),
    ("tpu_v5e", "fp16"): (0, 0.0008250252453413174),
    ("tpu_v5e", "fp32"): (0, 0.003274393535047619),
    ("cpu_host", "fp16"): (0, 0.34361738368),
    ("cpu_host", "fp32"): (0, 1.1453446122666666),
}

TILES = [TileConfig(bm, bn, bk) for bm in (64, 128, 256)
         for bn in (64, 128, 256) for bk in (16, 32, 64)]

NEW_ENTRIES = ("h100", "a100", "mi300x", "mi250x_gcd", "tpu_v4",
               "tpu_v6e", "cpu_roofline")


def data_files():
    return sorted(fn for fn in os.listdir(hardware.DATA_DIR)
                  if fn.endswith(".json"))


# ---------------------------------------------------------------------------
# Golden parity: the data files are the old constructors, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,precision", sorted(GOLDEN_ARGMIN))
def test_golden_argmin_parity(name, precision):
    gi, gt = GOLDEN_ARGMIN[(name, precision)]
    hw = hardware.get(name)
    table = WorkloadTable.tile_lattice(
        gemm_workload("golden", 4096, 4096, 4096, precision=precision),
        TILES)
    win = sweep.argmin_table(table, hw,
                             engine=sweep.SweepEngine(use_cache=False))
    assert (win.index, win.total) == (gi, gt)


def test_preset_attributes_resolve_to_registry_instances():
    # hardware.B200 et al. must be the registry's single memoized
    # instance — the sweep cache's per-instance token stash relies on it
    assert hardware.B200 is hardware.get("b200")
    assert hardware.TPU_V5E is hardware.get("tpu_v5e")
    assert hardware.CPU_HOST is hardware.get("cpu_host")
    with pytest.raises(AttributeError):
        hardware.NOT_A_PRESET


def test_new_accelerators_ship_as_data_and_price():
    engine = sweep.SweepEngine(use_cache=False)
    w = gemm_workload("g", 2048, 2048, 2048, precision="fp32")
    for name in NEW_ENTRIES:
        hw = hardware.get(name)
        assert hwlib.library_file(name) is not None, name
        t = engine.predict(w, hw).total
        assert 0.0 < t < 10.0, (name, t)


# ---------------------------------------------------------------------------
# Round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn", data_files())
def test_every_data_file_round_trips_bit_exactly(fn):
    path = os.path.join(hardware.DATA_DIR, fn)
    entry = hwlib.load_file(path)
    p = entry.params
    # dict round trip, including frozen cache_levels tuples
    q = hwlib.from_dict(hwlib.to_dict(p), where=fn)
    assert q == p
    assert isinstance(q.cache_levels, tuple)
    assert q.cache_levels == p.cache_levels
    # document round trip preserves provenance/units/source/notes
    again = hwlib.load_entry(entry.to_doc(), where=fn)
    assert again.params == p
    assert again.to_doc() == entry.to_doc()
    # JSON text round trip (what the wire does to the document)
    assert hwlib.load_entry(json.loads(json.dumps(entry.to_doc())),
                            where=fn).params == p


def test_sweep_content_token_never_serializes():
    hw = hardware.get("b200")
    sweep.hardware_key(hw)                       # stashes the token
    assert hasattr(hw, "_sweep_content_token")
    d = hwlib.to_dict(hw)
    assert "_sweep_content_token" not in d
    assert "_sweep_content_token" not in json.dumps(d)
    for fn in data_files():
        with open(os.path.join(hardware.DATA_DIR, fn)) as f:
            assert "_sweep_content_token" not in f.read(), fn


# ---------------------------------------------------------------------------
# Loader rejections: pointed errors, not KeyErrors from deep inside
# ---------------------------------------------------------------------------

def _b200_doc():
    return hwlib.load_file(
        os.path.join(hardware.DATA_DIR, "b200.json")).to_doc()


def test_loader_rejects_unknown_field_with_suggestion():
    doc = _b200_doc()
    doc["params"]["hbm_peak_bww"] = 1.0
    with pytest.raises(hwlib.HardwareSchemaError,
                       match=r"unknown field 'hbm_peak_bww' "
                             r"\(did you mean 'hbm_peak_bw'\?\)"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_missing_required_fields():
    doc = _b200_doc()
    del doc["params"]["name"]
    with pytest.raises(hwlib.HardwareSchemaError,
                       match="missing required field.*name"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_wrong_units_declaration():
    doc = _b200_doc()
    doc["units"] = {"hbm_peak_bw": "GB/s"}
    with pytest.raises(hwlib.HardwareSchemaError,
                       match=r"units\['hbm_peak_bw'\] is 'GB/s'.*"
                             r"rescale the value"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_unknown_precision():
    doc = _b200_doc()
    doc["params"]["tensor_peak_flops"]["fp7"] = 1.0
    with pytest.raises(hwlib.HardwareSchemaError,
                       match="unknown precision"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_unknown_model_family():
    doc = _b200_doc()
    doc["params"]["model_family"] = "hopperish"
    with pytest.raises(hwlib.HardwareSchemaError,
                       match="unknown model_family 'hopperish'"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_bad_provenance_tag():
    doc = _b200_doc()
    doc["provenance"] = {"hbm_peak_bw": "guessed"}
    with pytest.raises(hwlib.HardwareSchemaError,
                       match="tag 'guessed' not in"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_unknown_top_level_key():
    doc = _b200_doc()
    doc["paramz"] = {}
    with pytest.raises(hwlib.HardwareSchemaError,
                       match=r"unknown top-level key 'paramz'"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_bool_and_string_numbers():
    doc = _b200_doc()
    doc["params"]["num_sms"] = True
    with pytest.raises(hwlib.HardwareSchemaError, match="must be a number"):
        hwlib.load_entry(doc, where="t")
    doc = _b200_doc()
    doc["params"]["clock_ghz"] = "1.5"
    with pytest.raises(hwlib.HardwareSchemaError, match="must be a number"):
        hwlib.load_entry(doc, where="t")


def test_loader_rejects_malformed_cache_levels():
    doc = _b200_doc()
    doc["params"]["cache_levels"][0].pop("bandwidth")
    with pytest.raises(hwlib.HardwareSchemaError,
                       match=r"cache_levels\[0\] must have exactly"):
        hwlib.load_entry(doc, where="t")


def test_load_file_rejects_stem_mismatch_and_bad_json(tmp_path):
    doc = _b200_doc()
    p = tmp_path / "not_b200.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(hwlib.HardwareSchemaError,
                       match="file stem 'not_b200' must equal"):
        hwlib.load_file(str(p))
    bad = tmp_path / "broken.json"
    bad.write_text("{nope")
    with pytest.raises(hwlib.HardwareSchemaError, match="not valid JSON"):
        hwlib.load_file(str(bad))


def test_loader_rejects_wrong_schema_version():
    doc = _b200_doc()
    doc["schema_version"] = 99
    with pytest.raises(hwlib.HardwareSchemaError,
                       match="schema_version 99 unsupported"):
        hwlib.load_entry(doc, where="t")


# ---------------------------------------------------------------------------
# diff: the §V-E port as a query
# ---------------------------------------------------------------------------

def test_diff_b200_h200_names_exactly_the_port_fields():
    d = hwlib.diff(hardware.get("b200"), hardware.get("h200"))
    assert bool(d)
    assert set(d.fields()) == {
        "name", "num_sms", "hbm_peak_bw", "hbm_sustained_bw",
        "hbm_capacity", "tensor_peak_flops", "tensor_sustained_flops",
        "accum_capacity_bytes", "accum_read_bw", "accum_write_bw",
        "tma_bandwidth", "two_sm_speedup", "cache_levels",
    }
    # B200 has fp4 tensor cores, H200 does not: a removed sub-key
    assert "tensor_peak_flops.fp4" in d.removed
    assert "diff b200 -> h200" in d.format()


def test_diff_of_identical_params_is_empty():
    d = hwlib.diff(hardware.get("b200"), hardware.get("b200"))
    assert not d
    assert d.fields() == ()


# ---------------------------------------------------------------------------
# Registry semantics (satellite: collision raises; tombstone deletes)
# ---------------------------------------------------------------------------

def test_register_collision_raises_and_overwrite_replaces():
    orig = hardware.get("h200")
    try:
        with pytest.raises(ValueError, match="already registered.*"
                                             "overwrite=True"):
            hardware.register(orig.with_updates(hbm_sustained_bw=1.0))
        # collision fires even against a *not-yet-loaded* data file
        fresh = hardware._LazyRegistry()
        reg, hardware.REGISTRY = hardware.REGISTRY, fresh
        try:
            assert "mi300x" not in fresh._loaded
            with pytest.raises(ValueError, match="already registered"):
                hardware.register(orig.with_updates(name="mi300x"))
        finally:
            hardware.REGISTRY = reg
        changed = orig.with_updates(hbm_sustained_bw=1.0)
        hardware.register(changed, overwrite=True)
        assert hardware.get("h200") is changed
    finally:
        hardware.REGISTRY["h200"] = orig


def test_register_rejects_non_hardware_params():
    with pytest.raises(TypeError, match="takes a HardwareParams"):
        hardware.register({"name": "x"})


def test_tombstone_delete_hides_file_backed_entry():
    orig = hardware.get("tpu_v4")
    try:
        del hardware.REGISTRY["tpu_v4"]
        assert "tpu_v4" not in hardware.REGISTRY
        with pytest.raises(KeyError, match="unknown hardware 'tpu_v4'"):
            hardware.get("tpu_v4")
    finally:
        hardware.REGISTRY["tpu_v4"] = orig
    assert hardware.get("tpu_v4") is orig


def test_install_goes_through_register(tmp_path):
    # a data file cannot silently shadow a shipped entry (satellite 1)
    doc = _b200_doc()
    doc["params"]["hbm_sustained_bw"] = 1.0
    p = tmp_path / "b200.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="already registered"):
        hwlib.install(str(p))
    assert hardware.get("b200").hbm_sustained_bw != 1.0


# ---------------------------------------------------------------------------
# Satellite: peak_flops validates precision first
# ---------------------------------------------------------------------------

def test_peak_flops_unknown_precision_is_pointed():
    hw = hardware.get("b200")
    with pytest.raises(KeyError, match=r"no peak flops for 'fp7' on "
                                       r"b200: unknown precision"):
        hw.peak_flops("fp7")
    # a *known* precision a lacking entry can't scale-fallback for still
    # errors (vector tables have no byte-ratio fallback)
    with pytest.raises(KeyError, match="no peak flops"):
        hardware.get("tpu_v5e").peak_flops("fp4", matrix=False)


# ---------------------------------------------------------------------------
# Satellite: fit_per_case / fit_per_class record skipped kernels
# ---------------------------------------------------------------------------

def _suite():
    ws = [gemm_workload(f"g{n}", n, n, n, precision="fp32")
          for n in (512, 1024, 2048, 4096)]
    return ws, [1e-3, 2e-3, 8e-3, 3e-2]


def test_fit_per_case_records_skipped_kernels():
    ws, meas = _suite()
    eng = sweep.SweepEngine(use_cache=False)
    hw = hardware.get("b200")

    def degenerate(w):
        tb = eng.predict(w, hw)
        return tb.scaled(0.0) if w.name == "g1024" else tb

    cal = calibrate.fit_per_case(ws, meas, degenerate)
    assert cal.skipped == ["g1024"]
    assert "g1024" not in cal.per_case
    assert cal.disclose()["skipped"] == ["g1024"]
    # the all-zero backend yields no multipliers, not a silent 0% MAE
    cal0 = calibrate.fit_per_case(ws, meas,
                                  lambda w: eng.predict(w, hw).scaled(0.0))
    assert cal0.per_case == {} and len(cal0.skipped) == len(ws)


def test_fit_with_holdout_reports_n_skipped():
    ws, meas = _suite()
    eng = sweep.SweepEngine(use_cache=False)
    hw = hardware.get("b200")
    meas[2] = 0.0                      # non-positive measurement
    cal, report = calibrate.fit_with_holdout(
        ws, meas, lambda w: eng.predict(w, hw), mode="class", seed=0)
    assert report["n_skipped"] == float(len(cal.skipped))
    # Calibration round trip carries the skip list (§IV-D disclosure)
    again = calibrate.Calibration.from_dict(cal.to_dict())
    assert again.to_dict() == cal.to_dict()


def test_calibration_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown calibration key"):
        calibrate.Calibration.from_dict({"per_case": {}, "scale": 2.0})


# ---------------------------------------------------------------------------
# CI gate: the schema lint runs clean as a subprocess (tier-1 wiring)
# ---------------------------------------------------------------------------

def test_check_hwlib_gate_passes():
    root = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_hwlib", "-q"],
        cwd=root, env=env, capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "hwlib check OK" in out.stdout

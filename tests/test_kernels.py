"""Per-kernel validation: shape/dtype sweeps vs pure-jnp oracles,
interpret=True (TPU kernels executed in Python on CPU).

The exhaustive interpret-mode sweeps take minutes and are marked ``slow``;
the fast tier-1 gate (-m "not slow") keeps one cheap test per kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fa_kernel, ops as fa_ops, \
    ref as fa_ref
from repro.kernels.matmul import kernel as mm_kernel, ops as mm_ops, \
    ref as mm_ref
from repro.kernels.rmsnorm import kernel as rms_kernel, ops as rms_ops, \
    ref as rms_ref
from repro.kernels.ssd import kernel as ssd_kernel, ops as ssd_ops, \
    ref as ssd_ref

KEY = jax.random.PRNGKey(42)

TOL = {jnp.float32: 5e-5, jnp.bfloat16: 5e-2}


def tol_for(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


class TestFlashAttention:
    @pytest.mark.slow
    @pytest.mark.parametrize("b,hq,hkv,s,d", [
        (1, 2, 2, 128, 64),
        (2, 4, 2, 256, 64),     # GQA group 2
        (1, 8, 1, 128, 32),     # MQA
        (1, 2, 2, 384, 128),    # non-pow2 seq blocks
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, b, hq, hkv, s, d, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
        k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
        v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
        out = fa_kernel.mha(q, k, v, sm_scale=d ** -0.5, causal=True,
                            block_q=64, block_kv=64)
        exp = fa_ref.attention(q, k, v, sm_scale=d ** -0.5, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            atol=tol_for(dtype), rtol=tol_for(dtype))

    @pytest.mark.slow
    @pytest.mark.parametrize("window", [32, 64, 200])
    def test_sliding_window(self, window):
        b, h, s, d = 1, 2, 256, 64
        ks = jax.random.split(KEY, 3)
        q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32)
                   for kk in ks)
        out = fa_kernel.mha(q, k, v, sm_scale=d ** -0.5, causal=True,
                            window=window, block_q=64, block_kv=64)
        exp = fa_ref.attention(q, k, v, sm_scale=d ** -0.5, causal=True,
                               window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=5e-5, rtol=5e-5)

    def test_non_causal(self):
        b, h, s, d = 1, 2, 128, 64
        ks = jax.random.split(KEY, 3)
        q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32)
                   for kk in ks)
        out = fa_kernel.mha(q, k, v, sm_scale=d ** -0.5, causal=False,
                            block_q=64, block_kv=64)
        exp = fa_ref.attention(q, k, v, sm_scale=d ** -0.5, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=5e-5, rtol=5e-5)

    @pytest.mark.slow
    def test_block_size_invariance(self):
        """Output must not depend on the BlockSpec tiling."""
        b, h, s, d = 1, 2, 256, 64
        ks = jax.random.split(KEY, 3)
        q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32)
                   for kk in ks)
        outs = [fa_kernel.mha(q, k, v, sm_scale=0.125, causal=True,
                              block_q=bq, block_kv=bk)
                for bq, bk in ((32, 32), (64, 128), (128, 64), (256, 256))]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=2e-5, rtol=2e-5)

    def test_ops_fallback_odd_seq(self):
        """Odd sequence lengths route to the oracle transparently."""
        b, h, s, d = 1, 2, 100, 64
        ks = jax.random.split(KEY, 3)
        q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32)
                   for kk in ks)
        out = fa_ops.flash_attention(q, k, v)
        exp = fa_ref.attention(q, k, v, sm_scale=d ** -0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-5, rtol=1e-5)


class TestMatmul:
    @pytest.mark.slow
    @pytest.mark.parametrize("m,n,k", [
        (128, 128, 128), (256, 512, 384), (512, 256, 1024), (64, 64, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, m, n, k, dtype):
        a = jax.random.normal(KEY, (m, k), dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
        out = mm_kernel.matmul_tiled(a, b, bm=128, bn=128, bk=128)
        exp = mm_ref.matmul(a, b)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            atol=tol_for(dtype) * k ** 0.5, rtol=tol_for(dtype))

    @pytest.mark.slow
    def test_block_invariance(self):
        a = jax.random.normal(KEY, (256, 256), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
        outs = [mm_kernel.matmul_tiled(a, b, bm=bm, bn=bn, bk=bk)
                for bm, bn, bk in ((64, 64, 64), (128, 256, 128),
                                   (256, 128, 256))]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=1e-4, rtol=1e-5)

    def test_model_driven_block_selection(self):
        """ops.select_blocks returns the analytical argmin (paper's
        adaptive tile selection on TPU BlockSpecs)."""
        best, costs = mm_ops.select_blocks(4096, 4096, 4096)
        assert costs[best] == min(costs.values())
        assert len(costs) >= 4


class TestRMSNorm:
    @pytest.mark.slow
    @pytest.mark.parametrize("r,d", [(8, 64), (256, 512), (1024, 128),
                                     (100, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, r, d, dtype):
        x = jax.random.normal(KEY, (r, d), dtype)
        w = jax.random.normal(jax.random.PRNGKey(7), (d,), dtype)
        out = rms_kernel.rmsnorm_2d(x, w, block_rows=64)
        exp = rms_ref.rmsnorm(x, w)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            atol=tol_for(dtype), rtol=tol_for(dtype))

    def test_leading_dims_flatten(self):
        x = jax.random.normal(KEY, (2, 3, 16, 64), jnp.float32)
        w = jnp.ones((64,), jnp.float32)
        out = rms_ops.rmsnorm(x, w)
        exp = rms_ref.rmsnorm(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-5, rtol=1e-5)

    def test_unit_weight_normalizes(self):
        x = 3.0 * jax.random.normal(KEY, (64, 128), jnp.float32)
        out = rms_ops.rmsnorm(x, jnp.ones((128,)))
        rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, atol=1e-3)


class TestSSD:
    @pytest.mark.slow
    @pytest.mark.parametrize("b,s,h,p,n,chunk", [
        (1, 128, 2, 16, 32, 32),
        (2, 256, 3, 16, 32, 64),
        (1, 256, 2, 32, 64, 128),
        (1, 64, 1, 8, 16, 64),      # chunk == seq
    ])
    def test_sweep_vs_sequential_scan(self, b, s, h, p, n, chunk):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a_log = 0.5 * jax.random.normal(ks[2], (h,))
        bm = jax.random.normal(ks[3], (b, s, n)) / np.sqrt(n)
        cm = jax.random.normal(ks[4], (b, s, n)) / np.sqrt(n)
        out = ssd_kernel.ssd(x, dt, a_log, bm, cm, chunk=chunk)
        exp = ssd_ref.ssd_scan_ref(x, dt, a_log, bm, cm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=5e-4, rtol=5e-3)

    @pytest.mark.slow
    def test_chunk_invariance(self):
        """Chunked SSD must equal the recurrence regardless of chunking."""
        b, s, h, p, n = 1, 128, 2, 16, 32
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a_log = 0.5 * jax.random.normal(ks[2], (h,))
        bm = jax.random.normal(ks[3], (b, s, n)) / np.sqrt(n)
        cm = jax.random.normal(ks[4], (b, s, n)) / np.sqrt(n)
        outs = [ssd_kernel.ssd(x, dt, a_log, bm, cm, chunk=c)
                for c in (32, 64, 128)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=2e-4, rtol=2e-3)

    def test_decay_stability(self):
        """Large dt*A: state must decay, outputs bounded (no NaN/Inf)."""
        b, s, h, p, n = 1, 128, 1, 8, 16
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = 10.0 * jnp.ones((b, s, h))
        a_log = jnp.ones((h,)) * 2.0     # strongly negative A
        bm = jax.random.normal(ks[3], (b, s, n))
        cm = jax.random.normal(ks[4], (b, s, n))
        out = ssd_kernel.ssd(x, dt, a_log, bm, cm, chunk=64)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_ops_fallback(self):
        """Non-divisible seq routes to the exact scan."""
        b, s, h, p, n = 1, 100, 1, 8, 16
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a_log = 0.5 * jax.random.normal(ks[2], (h,))
        bm = jax.random.normal(ks[3], (b, s, n))
        cm = jax.random.normal(ks[4], (b, s, n))
        out = ssd_ops.ssd_scan(x, dt, a_log, bm, cm, chunk=64)
        exp = ssd_ref.ssd_scan_ref(x, dt, a_log, bm, cm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-5, rtol=1e-5)

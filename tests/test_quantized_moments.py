"""Int8 block-quantized Adam moments: roundtrip accuracy, convergence
parity with fp32 AdamW, and the memory-budget arithmetic that motivates it
(§Perf: 671B params on a 256x16GB pod)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw_init, adamw_update
from repro.optim.quantized_moments import (dequantize_nonneg,
                                           dequantize_signed,
                                           moment_bytes_per_param, q8_init,
                                           q8_adamw_update, quantize_nonneg,
                                           quantize_signed)


class TestQuantRoundtrip:
    @pytest.mark.parametrize("n", [10, 256, 1000, 4096])
    def test_signed_roundtrip(self, n):
        x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.1
        q, s = quantize_signed(x)
        y = dequantize_signed(q, s, (n,))
        rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        assert rel < 0.01, rel

    def test_nonneg_roundtrip(self):
        """Log-space quantization: bounded RELATIVE error per element —
        including the tiny ones (the property linear int8 lacks, which
        blew up mhat/sqrt(v))."""
        x = jax.random.uniform(jax.random.PRNGKey(0), (1000,)) ** 2
        q, s = quantize_nonneg(x)
        y = dequantize_nonneg(q, s, (1000,))
        rel_elem = jnp.abs(y - x) / jnp.maximum(x, 1e-12)
        assert float(jnp.max(rel_elem)) < 0.08
        assert bool(jnp.all(y >= 0))
        # small elements specifically must NOT flush to zero
        small = x < jnp.percentile(x, 10)
        assert bool(jnp.all(y[small] > 0))

    def test_blockwise_handles_scale_variation(self):
        """Per-block scales keep relative error bounded even when
        magnitudes vary 1e6x across blocks (global scale would not)."""
        a = jax.random.normal(jax.random.PRNGKey(0), (256,))
        b = jax.random.normal(jax.random.PRNGKey(1), (256,)) * 1e-6
        x = jnp.concatenate([a, b])
        q, s = quantize_signed(x)
        y = dequantize_signed(q, s, (512,))
        rel_b = float(jnp.linalg.norm(y[256:] - b) / jnp.linalg.norm(b))
        assert rel_b < 0.01, rel_b


class TestQ8Adam:
    def test_quadratic_convergence(self):
        params = {"w": jnp.array([3.0, -2.0, 1.5, -0.5])}
        state = q8_init(params)
        for _ in range(300):
            grads = {"w": 2 * params["w"]}
            params, state, _ = q8_adamw_update(params, grads, state,
                                               lr=0.05, weight_decay=0.0)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.25

    def test_tracks_fp32_adamw(self):
        """Over 30 steps on a noisy quadratic, q8 parameters stay close to
        the fp32-AdamW trajectory."""
        key = jax.random.PRNGKey(0)
        w0 = jax.random.normal(key, (512,))
        p_fp = {"w": w0}
        p_q8 = {"w": w0}
        s_fp = adamw_init(p_fp)
        s_q8 = q8_init(p_q8)
        for i in range(30):
            g = {"w": 2 * p_fp["w"]
                 + 0.01 * jax.random.normal(jax.random.PRNGKey(i), (512,))}
            p_fp, s_fp, _ = adamw_update(p_fp, g, s_fp, lr=0.01,
                                         weight_decay=0.0)
            g2 = {"w": 2 * p_q8["w"]
                  + 0.01 * jax.random.normal(jax.random.PRNGKey(i), (512,))}
            p_q8, s_q8, _ = q8_adamw_update(p_q8, g2, s_q8, lr=0.01,
                                            weight_decay=0.0)
        drift = float(jnp.linalg.norm(p_fp["w"] - p_q8["w"])
                      / jnp.linalg.norm(p_fp["w"]))
        assert drift < 0.05, drift

    def test_state_dtypes_are_int8(self):
        params = {"w": jnp.zeros((300,))}
        state = q8_init(params)
        assert state["mu"]["w"]["q"].dtype == jnp.int8
        assert state["nu"]["w"]["q"].dtype == jnp.int8

    def test_memory_budget_math(self):
        """The §Perf motivation: deepseek-v3-671b optimizer+params per chip
        on a 256-chip pod drops below the 16 GB HBM budget with q8
        moments + fp32-free params (bf16)."""
        n = 671e9
        chips = 256
        bf16_all = n * (2 + 2 + 2) / chips          # p + m + v bf16
        q8 = n * (2 + moment_bytes_per_param()) / chips
        assert bf16_all > 15.5e9                    # the baseline overflow
        assert q8 < 11e9                            # fits with room for act


class TestQ8ShapePreserving:
    """§Perf #6 fix: the nd layout keeps leading dims (and therefore the
    weights' TP/EP shardings) intact."""

    def test_nd_roundtrip(self):
        from repro.optim.quantized_moments import (dequantize_signed_nd,
                                                   quantize_signed_nd)
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 520)) * 0.1
        q, s = quantize_signed_nd(x)
        assert q.shape == (4, 6, 3, 256)       # leading dims preserved
        assert s.shape == (4, 6, 3)
        y = dequantize_signed_nd(q, s, x.shape)
        rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        assert rel < 0.01

    def test_nd_adam_tracks_fp32(self):
        from repro.optim.quantized_moments import q8nd_adamw_update, \
            q8nd_init
        key = jax.random.PRNGKey(0)
        w0 = jax.random.normal(key, (8, 320))
        p_fp = {"w": w0}
        p_q8 = {"w": w0}
        s_fp = adamw_init(p_fp)
        s_q8 = q8nd_init(p_q8)
        for i in range(30):
            g = {"w": 2 * p_fp["w"]}
            p_fp, s_fp, _ = adamw_update(p_fp, g, s_fp, lr=0.01,
                                         weight_decay=0.0)
            g2 = {"w": 2 * p_q8["w"]}
            p_q8, s_q8, _ = q8nd_adamw_update(p_q8, g2, s_q8, lr=0.01,
                                              weight_decay=0.0)
        drift = float(jnp.linalg.norm(p_fp["w"] - p_q8["w"])
                      / jnp.linalg.norm(p_fp["w"]))
        assert drift < 0.05, drift

    def test_nd_spec_inherits_parent_sharding(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding
        rules = dict(sharding.DEFAULT_RULES)
        # expert weight (E, D, F): q (E, D, nb, 256) must keep (tp, fsdp)
        spec = sharding.leaf_spec(
            "opt/mu/groups/b0/moe/we_g/q", (64, 128, 8, 256),
            rules=rules, stacked=False,
            mesh_shape={"data": 4, "model": 2})
        assert spec == P("model", "data", None, None), spec
        # scale for nonneg (E, D, nb, 2)
        spec = sharding.leaf_spec(
            "opt/nu/groups/b0/moe/we_g/scale", (64, 128, 8, 2),
            rules=rules, stacked=False,
            mesh_shape={"data": 4, "model": 2})
        assert spec == P("model", "data", None, None), spec

"""§Perf hillclimb switches: correctness parity with the baselines.

The optimized paths must be numerically equivalent — the §Perf wins come
from communication/memory scheduling, not changed math."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_mtp_share_trunk_identical_loss():
    cfg = get_config("deepseek-v3-671b", smoke=True)
    model_base = build(cfg)
    model_opt = build(cfg.replace(mtp_share_trunk=True))
    params = model_base.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    l0, m0 = model_base.loss_fn(params, batch)
    l1, m1 = model_opt.loss_fn(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    np.testing.assert_allclose(float(m0["mtp"]), float(m1["mtp"]),
                               rtol=1e-5)


def test_ssd_shard_map_matches_gspmd():
    """Run the mamba2 smoke forward with and without shard_map SSD on an
    8-device subprocess mesh; outputs must match."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed import sharding
        from repro.models import build

        cfg = get_config("mamba2-1.3b", smoke=True).replace(
            ssm_headdim=16, d_model=64)
        model0 = build(cfg)
        model1 = build(cfg.replace(ssd_shard_map=True))
        params = model0.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with sharding.use_mesh(mesh, {}):
            l0 = jax.jit(lambda p, t: model0.forward(p, t)[0])(params, toks)
            l1 = jax.jit(lambda p, t: model1.forward(p, t)[0])(params, toks)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   atol=2e-4, rtol=2e-3)
        # gradients too
        def loss(m):
            def f(p):
                lg, _ = m.forward(p, toks)
                return jnp.sum(lg.astype(jnp.float32) ** 2)
            return f
        with sharding.use_mesh(mesh, {}):
            g0 = jax.jit(jax.grad(loss(model0)))(params)
            g1 = jax.jit(jax.grad(loss(model1)))(params)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=3e-3, rtol=3e-2)
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_q8_moments_smoke_training():
    """Full train step with int8 moments on a smoke config: loss drops."""
    from repro.data import SyntheticLMData
    from repro.train.train_step import init_state, make_train_step
    cfg = get_config("minicpm-2b", smoke=True)
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0), moment_dtype="int8")
    data = SyntheticLMData(cfg, batch=8, seq_len=32)
    step = jax.jit(make_train_step(model, lr=3e-3, q8_moments=True))
    losses = []
    for i in range(20):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert sum(losses[-5:]) / 5 < sum(losses[:5]) / 5 - 0.1
    # moments really are int8
    leaf = jax.tree.leaves(state["opt"]["mu"])[0]
    assert leaf.dtype == jnp.int8

"""Model-driven plan selection: the paper's adaptive tile selection
(§IV-B) at two levels.

1. GEMM tiles on MI300A (the paper's own study: 16x16 beats 8x8).
2. Pallas BlockSpec selection for the TPU matmul kernel.
3. SPMD execution-plan selection for llama3-405b train_4k on the
   production mesh (TP degree x microbatches x remat x int8-grads) —
   the generalization that drives §Perf hillclimbing.

Run:  PYTHONPATH=src python examples/autotune_plan.py
"""
from repro.core import autotune, cdna3, collectives, hardware
from repro.core.workload import TileConfig, gemm_workload
from repro.configs import get_config
from repro.kernels.matmul.ops import select_blocks


def tile_selection_mi300a():
    print("=" * 60)
    print("1. MI300A tile selection (paper Eq. 14)")
    print("=" * 60)
    base = gemm_workload("g4096", 4096, 4096, 4096, precision="fp32")
    tiles = [TileConfig(s, s, 16) for s in (8, 16, 32, 64)]
    best, costs = cdna3.adaptive_tile_selection(base, hardware.MI300A,
                                                tiles)
    for tag, t in sorted(costs.items(), key=lambda kv: kv[1]):
        mark = " <- selected" if tag.startswith(f"{best.bm}x") else ""
        print(f"  tile {tag:12s}: {t * 1e6:9.2f} us{mark}")


def blockspec_selection_tpu():
    print()
    print("=" * 60)
    print("2. Pallas BlockSpec selection (TPU matmul kernel)")
    print("=" * 60)
    best, costs = select_blocks(8192, 8192, 8192)
    for blocks, t in sorted(costs.items(), key=lambda kv: kv[1]):
        mark = " <- selected" if blocks == best else ""
        print(f"  blocks {str(blocks):18s}: {t * 1e3:8.3f} ms{mark}")


def plan_selection_405b():
    print()
    print("=" * 60)
    print("3. SPMD plan selection: llama3-405b train_4k on 16x16 v5e")
    print("=" * 60)
    cfg = get_config("llama3-405b")
    mesh = collectives.MeshSpec(axes=(("data", 16), ("model", 16)))
    n = cfg.param_count()
    candidates = []
    for ub in (1, 8, 16):
        for remat in ("block", "full"):
            for comp in (False, True):
                candidates.append(autotune.PlanCandidate(
                    name=f"ub{ub}-{remat}{'-int8' if comp else ''}",
                    mesh=mesh, tp_degree=16, microbatches=ub,
                    remat=remat, compressed_grads=comp))
    tokens = 256 * 4096
    best, costs = autotune.select_plan(
        candidates,
        model_flops=6.0 * n * tokens,
        param_bytes=2.0 * n,
        activation_bytes=2.0 * tokens * cfg.d_model * cfg.n_layers * 4,
        opt_state_bytes=4.0 * n,
        activation_peak_bytes=2.0 * tokens * cfg.d_model * 2,
    )
    for c in sorted(costs, key=lambda c: c.total_s):
        feas = "fits " if c.detail.get("feasible") else "OOM  "
        mark = " <- selected" if c.plan.name == best.plan.name else ""
        print(f"  {c.plan.name:16s} [{feas}] step {c.total_s:7.3f}s "
              f"(compute {c.compute_s:6.3f} coll-exposed "
              f"{c.exposed_collective_s:6.3f}){mark}")


if __name__ == "__main__":
    tile_selection_mi300a()
    blockspec_selection_tpu()
    plan_selection_405b()

"""Serving predictions across processes (the repo's first wire scenario).

Starts the HTTP prediction server as a real subprocess, then drives it
with ``repro.serve.PredictionClient``:

  1. a 10k-row GEMM tile-lattice ``WorkloadTable`` shipped over the wire
     and reduced server-side (argmin + top-k), answer checked bit-exact
     against the in-process fused reduction;
  2. the same request replayed — served from the engine's whole-table
     memo cache (watch the hit counters move);
  3. eight client threads firing small per-shape lattices concurrently —
     the server coalesces them into fused columnar evaluations;
  4. a ~1M-row lazy ``LatticeSpec`` sent as a tiny plan (a few hundred
     bytes on the wire) and streamed server-side in O(chunk) memory;
  5. the framed persistent-socket transport (binary framing v1): the
     server also opens ``--binary-port``, the client auto-negotiates it
     via ``/v1/health``, and a burst of single-row requests is pipelined
     over one socket — then deduped server-side when the tables repeat;
  6. the observability surface: everything above was instrumented as it
     ran, so the demo ends by fetching ``/v1/metrics`` and rendering the
     busiest latency histograms as a mini text dashboard.

``--metrics off`` and ``--slow-request-ms N`` are forwarded to the
server subprocess; the default slow threshold (250 ms) is low enough
that the ~1M-row streamed lattice emits a structured JSON slow-request
line on the server's stderr, trace id included.

Run:  PYTHONPATH=src python examples/serve_predictions.py
      PYTHONPATH=src python examples/serve_predictions.py --metrics off
"""
import argparse
import re
import threading
import time

from repro.core import hardware, sweep
from repro.core.workload import LatticeSpec, TileConfig, WorkloadTable, \
    gemm_workload
from repro.serve import PredictionClient
from repro.serve.subproc import (start_server_subprocess,
                                 stop_server_subprocess)

TILES = [TileConfig(bm, bn, bk)
         for bm in (32, 64, 128, 256) for bn in (32, 64, 128, 256)
         for bk in (8, 16, 32, 64)]
SHAPES = [(2048 + 512 * s, 4096, 4096) for s in range(160)]


def _quantile_bound(buckets, count, q):
    """Smallest bucket bound holding at least the q-th observation."""
    target = q * count
    for bound, cum in buckets:
        if cum >= target:
            return bound
    return float("inf")


def _ms(bound):
    return "inf" if bound == float("inf") else f"{bound * 1e3:g}ms"


def metrics_dashboard(text, top=5):
    """The busiest ``*_seconds`` histograms from a Prometheus text
    exposition, one line each: count, mean, and p50/p99 upper bounds
    read off the fixed bucket ladder."""
    kinds = dict(
        re.findall(r"^# TYPE (\S+) (\S+)$", text, flags=re.MULTILINE))
    series = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        metric, _, val = line.rpartition(" ")
        base, _, lbl = metric.partition("{")
        lbl = lbl[:-1] if lbl.endswith("}") else ""
        for suffix in ("_bucket", "_sum", "_count"):
            fam = base[:-len(suffix)]
            if base.endswith(suffix) and kinds.get(fam) == "histogram" \
                    and fam.endswith("_seconds"):
                break
        else:
            continue
        le = None
        if suffix == "_bucket":
            le = re.search(r'le="([^"]*)"', lbl).group(1)
            lbl = re.sub(r',?le="[^"]*"', "", lbl).strip(",")
        s = series.setdefault((fam, lbl),
                              {"buckets": [], "sum": 0.0, "count": 0})
        if suffix == "_bucket":
            s["buckets"].append(
                (float("inf") if le == "+Inf" else float(le), float(val)))
        elif suffix == "_sum":
            s["sum"] = float(val)
        else:
            s["count"] = int(float(val))
    busiest = sorted(series.items(), key=lambda kv: -kv[1]["count"])
    lines = []
    for (fam, lbl), s in busiest[:top]:
        if not s["count"]:
            continue
        buckets = sorted(s["buckets"])
        name = f"{fam}{{{lbl}}}" if lbl else fam
        lines.append(
            f"{name:<58s} n={s['count']:<5d} "
            f"mean {s['sum'] / s['count'] * 1e3:8.2f}ms  "
            f"p50<={_ms(_quantile_bound(buckets, s['count'], 0.50))}  "
            f"p99<={_ms(_quantile_bound(buckets, s['count'], 0.99))}")
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="prediction-serving demo (see module docstring)")
    ap.add_argument("--metrics", choices=("on", "off"), default="on",
                    help="forwarded to the server subprocess; 'off' shows "
                         "the kill switch (the dashboard renders empty)")
    ap.add_argument("--slow-request-ms", type=float, default=250.0,
                    help="forwarded: server logs a structured JSON line "
                         "for sweeps slower than this (trace id included)")
    args = ap.parse_args(argv)
    extra = ["--metrics", args.metrics,
             "--slow-request-ms", str(args.slow_request_ms)]
    proc, host, port, bport = start_server_subprocess(extra, binary=True)
    client = PredictionClient(host, port)
    try:
        print(f"server pid {proc.pid} at {host}:{port} -> "
              f"{client.health()['status']}")

        # -- 1. a 10k-row table over the wire ---------------------------
        parts = [WorkloadTable.tile_lattice(
            gemm_workload(f"shape{j}", m, n, k, precision="fp16"),
            TILES[:64]) for j, (m, n, k) in enumerate(SHAPES)]
        table = WorkloadTable.concat(parts)
        t0 = time.perf_counter()
        win = client.argmin(table, "b200")
        dt = time.perf_counter() - t0
        ref = sweep.argmin_table(table, hardware.B200,
                                 engine=sweep.SweepEngine(use_cache=False))
        same = (win.index == ref.index and win.total == ref.total
                and win.breakdown == ref.breakdown)
        print(f"argmin over {len(table):,} wire rows: {win.name} "
              f"{win.total * 1e3:.3f} ms  [{dt * 1e3:.1f} ms round-trip, "
              f"bit-identical to in-process: {same}]")
        top = client.topk(table, "b200", 3)
        print("top-3:", [(w.name, f"{w.total * 1e3:.3f} ms") for w in top])

        # -- 2. replay hits the server's memo cache ---------------------
        before = client.cache_stats()["hits"]
        t0 = time.perf_counter()
        client.argmin(table, "b200")
        dt_replay = time.perf_counter() - t0
        print(f"replayed argmin: {dt_replay * 1e3:.1f} ms "
              f"({dt / max(dt_replay, 1e-9):.1f}x faster; engine hits "
              f"{before} -> {client.cache_stats()['hits']})")

        # -- 3. concurrent small requests coalesce ----------------------
        def ask(j):
            client.argmin(parts[j], "b200")
        threads = [threading.Thread(target=ask, args=(j,))
                   for j in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = client.cache_stats()
        print(f"8 concurrent small sweeps -> "
              f"{st['coalescer_fused_evaluations']} fused evaluation(s), "
              f"{st['coalescer_coalesced_requests']} requests coalesced")

        # -- 4. a ~1M-row lattice as a tiny wire plan -------------------
        base = gemm_workload("big", 8192, 8192, 8192, precision="fp16")
        spec = LatticeSpec.cartesian(
            base,
            k_tiles=[8 + 4 * i for i in range(64)],
            num_ctas=[32 + 8 * i for i in range(64)],
            tma_participants=[1, 2, 4, 8] * 4,
            concurrent_kernels=[1, 2] * 8)
        t0 = time.perf_counter()
        win = client.argmin(spec, "b200")
        dt = time.perf_counter() - t0
        print(f"streamed {spec.n_rows:,}-row lattice server-side in "
              f"{dt:.2f} s -> {win.name} {win.total * 1e3:.3f} ms")

        # -- 5. pipelined single-row bursts over the binary socket ------
        singles = [WorkloadTable.tile_lattice(
            gemm_workload(f"pipe{j}", 2048 + 128 * j, 4096, 4096,
                          precision="fp16"), TILES[:1])
            for j in range(16)]
        t0 = time.perf_counter()
        wins = client.argmin_many(singles, "b200")
        dt_pipe = time.perf_counter() - t0
        # repeat the burst: identical tables dedup into one evaluation
        before = client.cache_stats()["coalescer_deduped_requests"]
        client.argmin_many([singles[0]] * 16, "b200")
        saved = (client.cache_stats()["coalescer_deduped_requests"]
                 - before)
        print(f"binary on port {bport}: 16 pipelined single-row argmins "
              f"in {dt_pipe * 1e3:.1f} ms "
              f"({len(wins) / max(dt_pipe, 1e-9):.0f} req/s); repeating "
              f"one table 16x deduped {saved} request(s) server-side")

        # -- 6. the observability surface: /v1/metrics ------------------
        text = client.metrics_text()
        dash = metrics_dashboard(text)
        print(f"/v1/metrics ({len(text.splitlines())} exposition lines), "
              f"busiest latency histograms:")
        for line in dash:
            print(f"  {line}")
        if not dash:
            print("  (metrics disabled — rerun without --metrics off)")
    finally:
        client.close()
        stop_server_subprocess(proc)


if __name__ == "__main__":
    main()

"""Quickstart: the paper's model in 60 seconds.

1. Predict a GEMM's runtime on B200/MI300A/TPU-v5e with the analytical
   models (no hardware needed — the paper's procurement use case).
2. Show the naive-roofline failure the paper documents.
3. Train a tiny LM for a few steps with the full framework stack.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import hardware, predict, roofline
from repro.core.workload import gemm_workload, streaming_workload
from repro.launch.train import train


def perf_model_demo():
    print("=" * 64)
    print("1. Analytical prediction: GEMM 8192^3 across accelerators")
    print("=" * 64)
    w = gemm_workload("gemm_8192", 8192, 8192, 8192, precision="fp16")
    for name in ("b200", "mi300a", "h200", "mi250x", "tpu_v5e"):
        hw = hardware.get(name)
        wv = w.replace(precision="bf16") if name == "tpu_v5e" else w
        out = predict.predict(wv, hw)
        print(f"  {name:8s}: {out.total * 1e3:7.2f} ms "
              f"({out.dominant}-bound)")

    print()
    print("2. Why naive roofline fails (paper Table VI): a us-scale kernel")
    w2 = streaming_workload("vec_add_1MB", 1.5e6, flops_per_byte=1 / 12)
    for name in ("b200", "mi300a"):
        hw = hardware.get(name)
        t_model = predict.predict(w2, hw).total
        t_roof = roofline.predict(w2, hw).total
        print(f"  {name:8s}: model {t_model * 1e6:6.1f} us vs naive "
              f"roofline {t_roof * 1e6:6.2f} us "
              f"({t_model / t_roof:5.0f}x gap: launch + sustained-vs-peak)")


def training_demo():
    print()
    print("=" * 64)
    print("3. Train a tiny minicpm-family model (WSD schedule) 30 steps")
    print("=" * 64)
    out = train("minicpm-2b", smoke=True, steps=30, batch=8, seq=64,
                lr=3e-3, log_every=10)
    first, last = out["losses"][0], out["final_loss"]
    print(f"  loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    perf_model_demo()
    training_demo()

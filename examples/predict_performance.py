"""Cross-vendor performance prediction (the paper's §VI 'procurement
comparison between B200 and MI300A without access to both').

Sweeps a workload portfolio (GEMMs across sizes/precisions, bandwidth
kernels, a stencil app segment) over every parameter file, reporting
predicted time + bottleneck per platform — plus the TPU-v5e adaptation
with its collective stage on the production mesh, and two columnar sweeps
through ``WorkloadTable``: a ``tile_lattice`` + fused ``argmin_table``
tile search and a ``cartesian`` precision-x-concurrency what-if grid with
``topk_table``/``pareto_table`` (§IV-B adaptive tile selection at sweep
scale; benchmarks/sweep_bench.py is the 1,000-point version).

Ends with the streaming path: a 10M-config lazy ``LatticeSpec`` priced to
a fused argmin in O(chunk) peak memory (tracemalloc-verified), optionally
sharded across every core via ``core.parallel`` — the regime where
materializing the table first would cost gigabytes.

Run:  PYTHONPATH=src python examples/predict_performance.py
"""
import time
import tracemalloc

from repro.core import collectives, hardware, predict, sweep, tpu
from repro.core.workload import LatticeSpec, Segment, TileConfig, Workload, \
    WorkloadTable, gemm_workload, streaming_workload
from repro.core.segments import predict_app

PLATFORMS = ("b200", "h200", "mi300a", "mi250x", "tpu_v5e")


def portfolio():
    out = []
    for n in (2048, 8192, 16384):
        out.append(gemm_workload(f"gemm_fp16_{n}", n, n, n,
                                 precision="fp16"))
    out.append(gemm_workload("gemm_fp8_16384", 16384, 16384, 16384,
                             precision="fp8"))
    out.append(streaming_workload("stream_1GB", 1e9))
    out.append(Workload(name="stencil_8192", wclass="stencil",
                        flops=15.0 * 8192 ** 2, bytes=8.0 * 8192 ** 2,
                        precision="fp32",
                        working_set_bytes=2 * 8192 ** 2 * 4))
    return out


def main():
    ws = portfolio()
    print(f"{'workload':18s} | " + " | ".join(f"{p:>12s}" for p in PLATFORMS))
    print("-" * (20 + 15 * len(PLATFORMS)))
    for w in ws:
        cells = []
        for p in PLATFORMS:
            hw = hardware.get(p)
            wv = w
            if p == "tpu_v5e" and w.precision in ("fp16", "fp8"):
                wv = w.replace(precision="bf16")
            t = predict.predict(wv, hw)
            cells.append(f"{t.total * 1e3:8.2f}ms {t.dominant[:3]}")
        print(f"{w.name:18s} | " + " | ".join(f"{c:>12s}" for c in cells))

    print()
    print("Multi-chip (TPU v5e pod): same GEMM data-parallel across 256"
          " chips with the gradient all-reduce priced by the collective"
          " model:")
    mesh = collectives.MeshSpec(axes=(("data", 16), ("model", 16)))
    w = gemm_workload("gemm_bf16_16384", 16384, 16384, 16384,
                      precision="bf16")
    shard = w.replace(flops=w.flops / 256, bytes=w.bytes / 256)
    out = tpu.predict(shard, hardware.TPU_V5E, mesh=mesh,
                      collective_ops=[("all-reduce",
                                       16384 * 16384 * 2 / 256, "data")])
    print(f"  per-chip step {out.total * 1e3:.3f} ms; "
          f"collective {out.collective * 1e3:.3f} ms "
          f"(exposed {out.detail['t_coll_exposed'] * 1e3:.3f} ms)")

    print()
    print("Columnar tile sweep (WorkloadTable.tile_lattice + argmin_table):")
    print("price every (bM, bN, bK) tile candidate for an 8192^3 fp16 GEMM")
    print("without building per-config Workload objects, and take the fused")
    print("argmin (paper §IV-B adaptive tile selection at sweep scale):")
    base = gemm_workload("gemm8k", 8192, 8192, 8192, precision="fp16")
    tiles = [TileConfig(bm, bn, bk)
             for bm in (32, 64, 128, 256, 512)
             for bn in (32, 64, 128, 256, 512)
             for bk in (16, 32, 64, 128, 256)]
    table = WorkloadTable.tile_lattice(base, tiles)
    for plat in ("b200", "mi300a", "tpu_v5e"):
        hw = hardware.get(plat)
        t0 = time.perf_counter()
        win = sweep.argmin_table(table, hw)
        dt = time.perf_counter() - t0
        t = tiles[win.index]
        print(f"  {plat:8s}: {len(table)} tiles in {dt * 1e3:6.2f} ms"
              f" ({len(table) / dt:9.0f} cfg/s) -> best"
              f" {t.bm}x{t.bn}x{t.bk} @ {win.total * 1e3:.3f} ms"
              f" ({win.breakdown.dominant}-bound)")

    print()
    print("Cartesian what-if grid (WorkloadTable.cartesian): sweep the same")
    print("GEMM over precision x concurrency in one columnar cross-product,")
    print("then read the top-3 and the compute/memory pareto front:")
    grid = WorkloadTable.cartesian(
        base, precision=["fp16", "bf16", "fp8"],
        concurrent_kernels=[1, 2, 4])
    top = sweep.topk_table(grid, hardware.B200, 3)
    for w in top:
        print(f"  top: row {w.index} ({w.name}) @ {w.total * 1e3:.3f} ms")
    front = sweep.pareto_table(grid, hardware.B200,
                               objectives=("compute", "memory"))
    print(f"  pareto(compute, memory): {[w.index for w in front]}")

    print()
    print("Streamed 10M-config lattice (LatticeSpec + argmin_stream): the")
    print("same GEMM swept over a k_tiles x num_ctas x multicast x")
    print("concurrency occupancy grid.  The spec never materializes — ")
    print("chunks price through the engine one at a time, so peak memory")
    print("stays O(chunk) while the winner is bit-identical to pricing the")
    print("materialized table (which would need ~2.2 GB of columns here):")
    lattice = LatticeSpec.cartesian(
        base,
        k_tiles=[8 + 2 * i for i in range(128)],
        num_ctas=[16 + 4 * i for i in range(128)],
        tma_participants=[1, 2, 4, 8] * 4,
        concurrent_kernels=[1, 2, 4, 8] * 10)
    print(f"  lattice rows: {len(lattice):,} "
          f"(~{lattice.estimated_bytes() / 1e9:.1f} GB if materialized)")
    tracemalloc.start()
    t0 = time.perf_counter()
    win = sweep.argmin_stream(lattice, hardware.B200)
    dt = time.perf_counter() - t0
    peak_mb = tracemalloc.get_traced_memory()[1] / 1e6
    tracemalloc.stop()
    print(f"  serial stream : {len(lattice) / dt:12,.0f} cfg/s "
          f"({dt:.2f} s), peak memory {peak_mb:.1f} MB")
    print(f"    winner row {win.index} ({win.name}) @ "
          f"{win.total * 1e3:.4f} ms ({win.breakdown.dominant}-bound)")
    t0 = time.perf_counter()
    win_p = sweep.argmin_stream(lattice, hardware.B200, jobs=0)
    dt_p = time.perf_counter() - t0
    print(f"  sharded jobs=auto: {len(lattice) / dt_p:9,.0f} cfg/s "
          f"({dt_p:.2f} s) -> same winner: "
          f"{(win_p.index, win_p.total) == (win.index, win.total)}")

    print()
    print("Application segments (hotspot-like stencil app, 1000 iters):")
    seg = Segment(workload=Workload(
        name="hs_calc", wclass="stencil", flops=15.0 * 1024 ** 2,
        bytes=2.0 * 1024 ** 2 * 4.0, precision="fp32",
        working_set_bytes=2 * 1024 ** 2 * 4), n_exec=1000)
    for p in PLATFORMS:
        hw = hardware.get(p)
        app = predict_app("hotspot_1024", [seg], hw)
        print(f"  {p:8s}: {app.total * 1e3:8.2f} ms")


if __name__ == "__main__":
    main()

"""End-to-end training driver example: train a ~100M-parameter LM with the
full framework stack (deterministic data pipeline, WSD/cosine schedule,
grad clipping, async checkpointing, exact resume).

Default preset is CPU-sized so the example completes in minutes; pass
--preset 100m for the full-size run (same code path, more compute):

    PYTHONPATH=src python examples/train_lm.py                # cpu-small
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import tempfile

from repro.configs.base import ModelConfig
from repro.launch import train as train_mod

PRESETS = {
    # ~8M params: finishes on this container's CPU in a few minutes
    "cpu-small": dict(
        cfg=ModelConfig(name="lm-cpu-small", family="dense", n_layers=4,
                        d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                        vocab=4096, tie_embeddings=True),
        steps=60, batch=8, seq=128, lr=1e-3),
    # ~124M params (GPT2-small-ish): the assignment's "~100M for a few
    # hundred steps" target shape
    "100m": dict(
        cfg=ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                        vocab=32768, tie_embeddings=True),
        steps=300, batch=16, seq=512, lr=6e-4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-small", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    preset = PRESETS[args.preset]
    cfg = preset["cfg"]
    steps = args.steps or preset["steps"]
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm_ckpt_")

    print(f"[train_lm] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{steps} steps, ckpts -> {ckpt_dir}")

    # drive through the production trainer with a custom config
    import repro.configs.registry as registry
    import repro.configs as configs_pkg

    # register the preset as a selectable arch on the fly
    class _Mod:
        CONFIG = cfg
        SMOKE = cfg
    registry._MODULES[cfg.name] = cfg.name
    import sys
    sys.modules[f"repro.configs.{cfg.name}"] = _Mod

    out = train_mod.train(cfg.name, smoke=True, steps=steps,
                          batch=preset["batch"], seq=preset["seq"],
                          lr=preset["lr"], ckpt_dir=ckpt_dir,
                          ckpt_every=max(steps // 4, 10), log_every=10)
    print(f"[train_lm] loss {out['losses'][0]:.3f} -> "
          f"{out['final_loss']:.3f} over {len(out['losses'])} steps")
    print(f"[train_lm] resume test: re-invoking trainer picks up the "
          f"checkpoint in {ckpt_dir}")


if __name__ == "__main__":
    main()

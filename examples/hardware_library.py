"""The declarative hardware library, end to end.

The paper's portability claim (Obs. 6, §V-E) is that the models move
across accelerators by swapping parameter files, not formulas.  This CLI
drives that as data:

  list       every library entry (shipped data files + runtime registry)
  show       one entry: parameters, provenance tags, source citation
  diff       field-level delta between two entries — `diff b200 h200`
             prints exactly the §V-E port
  calibrate  the full served loop: start a prediction server subprocess,
             measure this host's real microbenchmark suite, upload it
             (POST /v1/calibrate), fit disclosed multipliers with
             train/holdout discipline server-side, register the fit, and
             price a tile sweep with and without it

Run:  PYTHONPATH=src python examples/hardware_library.py list
      PYTHONPATH=src python examples/hardware_library.py show b200
      PYTHONPATH=src python examples/hardware_library.py diff b200 h200
      PYTHONPATH=src python examples/hardware_library.py calibrate
"""
import argparse

from repro.core import hardware, hwlib


def cmd_list(args):
    print(f"{'name':14s} {'family':9s} {'units':>5s} "
          f"{'HBM GB':>7s} {'sust GB/s':>10s}  source")
    for name in sorted(hardware.REGISTRY):
        p = hardware.get(name)
        path = hwlib.library_file(name)
        src = ""
        if path is not None:
            entry = hwlib.load_file(path)
            if entry.params == p:
                src = entry.source.split(";")[0][:48]
        else:
            src = "(runtime registration)"
        print(f"{name:14s} {p.model_family:9s} {p.num_sms:5d} "
              f"{p.hbm_capacity / 1e9:7.0f} "
              f"{p.hbm_sustained_bw / 1e9:10.0f}  {src}")


def cmd_show(args):
    p = hardware.get(args.name)
    path = hwlib.library_file(args.name)
    entry = hwlib.load_file(path) if path else hwlib.HardwareEntry(params=p)
    print(f"{p.name}: {p.vendor} / {p.model_family}"
          + (f"  [{path}]" if path else "  [runtime registration]"))
    if entry.source:
        print(f"source: {entry.source}")
    if entry.notes:
        print(f"notes:  {entry.notes}")
    doc = hwlib.to_dict(p)
    for key in sorted(doc):
        tag = entry.provenance.get(key, "")
        unit = hwlib.FIELD_UNITS.get(key, "")
        print(f"  {key:28s} = {doc[key]!r:>40}  "
              f"{unit:8s} {('[' + tag + ']') if tag else ''}")


def cmd_diff(args):
    d = hwlib.diff(hardware.get(args.a), hardware.get(args.b))
    print(d.format())
    print(f"\nport touches {len(d.fields())} parameter field(s): "
          f"{', '.join(d.fields())}")


def cmd_calibrate(args):
    import numpy as np

    from repro.core.microbench import host_suite_result
    from repro.core.workload import TileConfig, WorkloadTable, gemm_workload
    from repro.serve import PredictionClient
    from repro.serve.subproc import (start_server_subprocess,
                                     stop_server_subprocess)

    hw_name = args.hw
    print(f"measuring the host microbenchmark suite (quick=True, "
          f"real timings through JAX)...")
    suite = host_suite_result(quick=True)
    print(f"  {len(suite)} kernels measured: "
          f"{', '.join(w.name for w in suite.workloads[:4])}, ...")

    proc, host, port = start_server_subprocess()
    client = PredictionClient(host, port)
    try:
        print(f"server pid {proc.pid} at {host}:{port} -> "
              f"{client.health()['status']}")
        cal, report = client.calibrate(
            suite, hw_name, mode=args.mode, register_as="host_fit")
        print(f"server fitted mode={args.mode} against its own "
              f"predictions for '{hw_name}':")
        for key, mult in sorted(cal.disclose().items()):
            print(f"  {key:20s} {mult if isinstance(mult, list) else f'{mult:.4g}'}")
        print(f"  train MAE {report['train_mae']:.2f}%  "
              f"holdout MAE {report['holdout_mae']:.2f}%  "
              f"(n={report['n_train']:.0f}/{report['n_holdout']:.0f}, "
              f"skipped {report['n_skipped']:.0f})")

        tiles = [TileConfig(bm, bn, bk)
                 for bm in (64, 128, 256) for bn in (64, 128, 256)
                 for bk in (16, 32, 64)]
        table = WorkloadTable.tile_lattice(
            gemm_workload("port", 4096, 4096, 4096, precision="fp32"),
            tiles)
        raw = client.predict_totals(table, hw_name)
        calibrated = client.predict_totals(table, hw_name,
                                           calibration="host_fit")
        win = client.argmin(table, hw_name, calibration="host_fit")
        scale = float(np.median(calibrated / raw))
        print(f"priced {len(table)} tile configs on '{hw_name}': "
              f"calibrated totals = raw x {scale:.4f}; winner "
              f"{win.name} at {win.total * 1e3:.3f} ms")
    finally:
        client.close()
        stop_server_subprocess(proc)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Browse, diff and served-calibrate the declarative "
                    "hardware library")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="every registry entry")
    show = sub.add_parser("show", help="one entry with provenance")
    show.add_argument("name")
    diffp = sub.add_parser("diff", help="field-level delta (the port)")
    diffp.add_argument("a")
    diffp.add_argument("b")
    calp = sub.add_parser(
        "calibrate",
        help="measure this host, upload, fit server-side, price with it")
    calp.add_argument("--hw", default="cpu_host",
                      help="registry entry to fit against")
    calp.add_argument("--mode", default="class", choices=("case", "class"))
    args = ap.parse_args(argv)
    {"list": cmd_list, "show": cmd_show, "diff": cmd_diff,
     "calibrate": cmd_calibrate}[args.cmd](args)


if __name__ == "__main__":
    main()

"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of
computing the table; derived = the table's headline result), then the full
tables.  ``python -m benchmarks.run [--full] [--skip-cpuhost]``.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="non-quick CPU-host measurements (slower)")
    ap.add_argument("--skip-cpuhost", action="store_true")
    ap.add_argument("--tables", default="",
                    help="comma-separated subset, e.g. table_vi,table_x")
    args = ap.parse_args()

    from . import tables as T

    benches = [
        ("table_ii_vii", lambda: T.table_ii_vii()),
        ("table_vi", lambda: T.table_vi()),
        ("table_x", lambda: T.table_x()),
        ("table_xi", lambda: T.table_xi()),
        ("table_xii", lambda: T.table_xii()),
        ("table_tiles", lambda: T.table_tiles()),
        ("table_2sm", lambda: T.table_2sm()),
        ("table_obs1", lambda: T.table_obs1()),
    ]
    if not args.skip_cpuhost:
        benches.append(("table_cpuhost",
                        lambda: T.table_cpuhost(quick=not args.full)))
    benches.append(("roofline_baseline", _roofline_table))

    subset = {t for t in args.tables.split(",") if t}
    results = []
    print("name,us_per_call,derived")
    for name, fn in benches:
        if subset and name not in subset:
            continue
        t0 = time.perf_counter()
        try:
            rows, derived = fn()
        except Exception as e:                            # noqa: BLE001
            print(f"{name},ERROR,{e!r}")
            continue
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}")
        results.append((name, rows))

    print()
    for name, rows in results:
        print(f"=== {name} ===")
        if not rows:
            print("(no rows)")
            continue
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))
        print()


def _roofline_table():
    """Roofline baseline rows from the dry-run JSONL (if present)."""
    import json
    import os
    rows = []
    for fname in ("dryrun_single.jsonl", "dryrun_multi.jsonl"):
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                d = json.loads(line)
                if d.get("status") != "ok":
                    rows.append({"cell": f"{d['arch']}x{d['shape']}"
                                         f"x{d['mesh']}",
                                 "dominant": "skipped",
                                 "compute_s": "", "memory_s": "",
                                 "collective_s": "", "useful": "",
                                 "fraction": ""})
                    continue
                rows.append({
                    "cell": f"{d['arch']}x{d['shape']}x{d['mesh']}",
                    "dominant": d["dominant"],
                    "compute_s": f"{d['compute_term_s']:.3e}",
                    "memory_s": f"{d['memory_term_s']:.3e}",
                    "collective_s": f"{d['collective_term_s']:.3e}",
                    "useful": f"{d['useful_flops_ratio']:.3f}",
                    "fraction": f"{d['roofline_fraction']:.3f}",
                })
    if not rows:
        return [], "run repro.launch.dryrun --all --json first"
    ok = [r for r in rows if r["dominant"] != "skipped"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return rows, f"{len(ok)} compiled cells; bottlenecks: {doms}"


if __name__ == "__main__":
    main()

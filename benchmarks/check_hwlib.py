"""Schema lint for the declarative hardware library.

A broken data file under ``src/repro/core/hwdata/`` would otherwise
surface as a confusing lazy-load failure deep inside a sweep (the
registry parses each file on first ``get()``).  This gate fails fast and
named instead.  Checks (exit 1 on any failure):

  * every ``hwdata/*.json`` validates against the ``hwlib`` schema
    (stem == entry name, known fields, canonical units, provenance tags),
  * round-trip determinism: ``from_dict(to_dict(params)) == params`` and
    re-serializing the loaded document reproduces it exactly — a file
    that does not round-trip would break wire-shipped entries,
  * the registry's lazy load is deterministic: two independent loads of
    the same file produce equal parameters, and the process registry
    ``get()`` memoizes to one instance (the sweep cache's per-instance
    token stash relies on this),
  * the six paper presets plus at least five data-only accelerators are
    present,
  * no data file shadows another entry's name and every entry prices a
    probe GEMM to a finite positive time on its routed backend.

Fast (< a few seconds, no jax import) — wired into tier-1 via
tests/test_hwlib.py.

Run:  PYTHONPATH=src python -m benchmarks.check_hwlib
"""
from __future__ import annotations

import argparse
import sys

REQUIRED = ("b200", "h200", "mi300a", "mi250x", "tpu_v5e", "cpu_host")
MIN_EXTRA = 5


def check(verbose: bool = True) -> list:
    from repro.core import hardware, hwlib, sweep
    from repro.core.workload import gemm_workload

    errors = []

    def say(msg):
        if verbose:
            print(msg)

    try:
        entries = hwlib.load_dir(hardware.DATA_DIR)
    except hwlib.HardwareSchemaError as e:
        return [f"schema: {e}"]
    names = [e.params.name for e in entries]
    say(f"validated {len(entries)} data file(s): {', '.join(names)}")

    if len(set(names)) != len(names):
        errors.append(f"duplicate entry names in {hardware.DATA_DIR}")
    missing = [n for n in REQUIRED if n not in names]
    if missing:
        errors.append(f"missing required preset file(s): {missing}")
    extra = [n for n in names if n not in REQUIRED]
    if len(extra) < MIN_EXTRA:
        errors.append(f"library ships only {len(extra)} data-only "
                      f"accelerator(s) beyond the presets (< {MIN_EXTRA})")

    engine = sweep.SweepEngine(use_cache=False)
    for entry in entries:
        name = entry.params.name
        where = entry.path or name
        # round trip: dict form and document form must be fixed points
        rt = hwlib.from_dict(hwlib.to_dict(entry.params), where=where)
        if rt != entry.params:
            errors.append(f"{where}: from_dict(to_dict(p)) != p")
        redoc = hwlib.load_entry(entry.to_doc(), where=where)
        if redoc.params != entry.params or redoc.to_doc() != entry.to_doc():
            errors.append(f"{where}: document does not round-trip")
        # lazy-load determinism: a second independent parse is equal...
        again = hwlib.load_file(entry.path) if entry.path else None
        if again is not None and again.params != entry.params:
            errors.append(f"{where}: two loads of the same file differ")
        # ...and the live registry memoizes one instance per name
        if hardware.get(name) is not hardware.get(name):
            errors.append(f"{name}: registry returns distinct instances")
        if hardware.get(name) != entry.params:
            errors.append(f"{name}: registry entry differs from its data "
                          f"file (shadowed?)")
        # the entry actually prices on its routed backend
        w = gemm_workload("probe", 1024, 1024, 1024, precision="fp32")
        t = engine.predict(w, hardware.get(name)).total
        if not (t > 0.0 and t < 1e6):
            errors.append(f"{name}: probe GEMM priced at {t!r}")
        elif verbose:
            say(f"  {name:14s} route={sweep.default_route(hardware.get(name)):9s} "
                f"probe gemm1024 fp32 -> {t * 1e3:.4f} ms")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate the hardware library data files and the "
                    "registry's lazy-load determinism")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)
    errors = check(verbose=not args.quiet)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if not errors:
        print("hwlib check OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

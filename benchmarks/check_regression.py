"""Sweep-throughput regression gate.

Runs fresh ``benchmarks.sweep_bench`` passes and compares them against the
committed BENCH_sweep.json.  Machine noise can only make a run *slower*,
so the gate takes the best observation per field across up to
``--attempts`` runs (stopping early once everything clears): a transient
stall flakes at most one attempt, while a genuine code regression fails
all of them.  Fails (exit 1) on:

  * any ``speedup_*`` ratio dropping more than ``--tolerance`` (default
    20%) below the committed value — within-run ratios (table vs batch vs
    scalar, timed in the same process) are immune to the host being
    globally slower/faster than the baseline machine, so they are the
    default signal,
  * with ``--absolute``, additionally any ``configs_per_sec_*`` field
    dropping more than ``--tolerance`` below the committed value — only
    meaningful on hardware comparable to (and as idle as) the machine
    that committed the baseline; shared/throttled runners swing absolute
    throughput ~1.5x with zero code change,
  * any correctness flag in the fresh run being false (bit-identity of
    the fused AND streamed/sharded reductions, cached-replay-beats-cold,
    table/list config parity, O(chunk) streamed peak memory).

The streamed/sharded routes add ``speedup_stream_vs_table`` and
``speedup_parallel_vs_table`` (big-lattice, within-run) to the gated
ratio set, plus ``big_*_bit_identical`` / ``stream_peak_bounded`` /
``stream_reduction_bit_identical`` to the correctness set.

``speedup_table_vs_pr1_batch`` is excluded from gating: it divides by a
frozen historical constant, so it is an absolute measurement in disguise
(it remains the bench's own >=3x acceptance criterion).

Run:  PYTHONPATH=src python -m benchmarks.check_regression
      PYTHONPATH=src python -m benchmarks.check_regression --absolute
      PYTHONPATH=src python -m benchmarks.check_regression --tolerance 0.3
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_sweep.json"))

#: fields that must be true in the fresh run regardless of timing
CORRECTNESS_FLAGS = ("cached_faster_than_cold",
                     "table_cached_faster_than_cold",
                     "table_same_configs_as_list",
                     "big_stream_bit_identical",
                     "big_parallel_bit_identical",
                     "stream_peak_bounded")
CORRECTNESS_DICTS = ("bit_identical_batch_of_1",
                     "argmin_table_bit_identical",
                     "stream_reduction_bit_identical")

#: not gated: ratios against frozen cross-run constants (absolute
#: measurements in disguise) and microsecond-scale replay throughputs
#: (covered by the *_faster_than_cold flags instead)
EXCLUDED_KEYS = ("speedup_table_vs_pr1_batch", "configs_per_sec_table_cached")


def _gated_keys(absolute: bool):
    prefixes = ("configs_per_sec", "speedup") if absolute else ("speedup",)

    def gated(key):
        return key.startswith(prefixes) and key not in EXCLUDED_KEYS
    return gated


def compare(fresh: dict, baseline: dict, tolerance: float, *,
            absolute: bool = False):
    """Return (regressions, correctness_failures) for the two runs."""
    gated = _gated_keys(absolute)
    regressions = []
    for key, base_val in baseline.items():
        if not gated(key):
            continue
        got = fresh.get(key)
        if got is None or got < base_val * (1.0 - tolerance):
            regressions.append((key, base_val, got))

    failures = []
    for key in CORRECTNESS_FLAGS:
        if key in fresh and not fresh[key]:
            failures.append(key)
    for key in CORRECTNESS_DICTS:
        for sub, ok in fresh.get(key, {}).items():
            if not ok:
                failures.append(f"{key}[{sub}]")
    return regressions, failures


def merge_best(attempts):
    """Fieldwise best across runs: max for numbers, OR for booleans (the
    correctness flags are within-run comparisons and flake the same way)."""
    best = dict(attempts[0])
    for run in attempts[1:]:
        for key, v in run.items():
            if isinstance(v, bool):
                best[key] = best.get(key, False) or v
            elif isinstance(v, dict):
                best[key] = {k: best.get(key, {}).get(k, False) or ok
                             for k, ok in v.items()}
            elif isinstance(v, (int, float)):
                best[key] = max(best.get(key, v), v)
    return best


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed BENCH_sweep.json to compare against")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional drop (0.2 = 20%%)")
    ap.add_argument("--attempts", type=int, default=3,
                    help="max bench reruns; the gate takes the best "
                         "observation per field (noise never speeds a run "
                         "up, so a real regression fails every attempt)")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate absolute configs_per_sec_* fields "
                         "(same-machine, idle-host runs only)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)

    from benchmarks.sweep_bench import run_bench
    attempts = []
    for i in range(max(args.attempts, 1)):
        attempts.append(run_bench())
        fresh = merge_best(attempts)
        regressions, failures = compare(fresh, baseline, args.tolerance,
                                        absolute=args.absolute)
        if not regressions and not failures:
            break
        if i + 1 < max(args.attempts, 1):
            print(f"attempt {i + 1}/{args.attempts}: "
                  f"{len(regressions)} field(s) below tolerance, retrying")

    gated = _gated_keys(args.absolute)
    width = max((len(k) for k in baseline if gated(k)), default=20)
    for key in sorted(baseline):
        if not gated(key):
            continue
        got = fresh.get(key, float("nan"))
        ratio = got / baseline[key] if baseline[key] else float("inf")
        flag = "REGRESSED" if any(k == key for k, _, _ in regressions) \
            else "ok"
        print(f"{key:{width}s}  baseline {baseline[key]:14.1f}  "
              f"fresh {got:14.1f}  ({ratio:5.2f}x)  {flag}")
    for key in failures:
        print(f"correctness flag failed: {key}")

    if regressions or failures:
        print(f"FAIL: {len(regressions)} regression(s) "
              f"(> {args.tolerance:.0%} drop), "
              f"{len(failures)} correctness failure(s)")
        return 1
    print(f"PASS: no gated field dropped more than "
          f"{args.tolerance:.0%} vs {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark regression gate: sweep throughput + serve throughput.

Runs fresh benchmark passes and compares them against the committed
baselines (``BENCH_sweep.json`` for ``benchmarks.sweep_bench``,
``BENCH_serve.json`` for ``benchmarks.serve_bench``).  Machine noise can
only make a run *slower*, so the gate takes the best observation per
field across up to ``--attempts`` runs (stopping early once everything
clears): a transient stall flakes at most one attempt, while a genuine
code regression fails all of them.  Fails (exit 1) on:

  * any ``speedup_*`` ratio dropping more than ``--tolerance`` (default
    20%) below the committed value — within-run ratios (table vs batch vs
    scalar, batched-request vs single-row, timed in the same process /
    against the same server) are immune to the host being globally
    slower/faster than the baseline machine, so they are the default
    signal,
  * with ``--absolute``, additionally any ``configs_per_sec_*`` /
    ``reqs_per_sec_*`` field dropping more than ``--tolerance`` below the
    committed value — only meaningful on hardware comparable to (and as
    idle as) the machine that committed the baseline,
  * any correctness flag in the fresh run being false.  Every top-level
    boolean field and every dict-of-booleans field in a bench row is a
    correctness flag (bit-identity of fused/streamed/sharded/served
    reductions, cached-replay-beats-cold, O(chunk) streamed peak memory,
    served answers matching in-process answers — including
    ``serve_binary_bit_identical`` / ``serve_dedup_bit_identical`` for
    the framed persistent-socket transport and its cross-request dedup
    — and availability under the serve bench's seeded chaos barrage:
    ``serve_chaos_all_completed`` / ``serve_chaos_all_correct`` assert
    every request survives injected stalls, truncations, bit flips and
    severed connections via typed-error retries, bit-identically),
  * any boolean the committed baseline carries going *missing* from the
    fresh run — a deleted or renamed flag must fail loudly, not silently
    drop its gate.

The serve suite's ``speedup_binary_vs_http_single`` ratio (binary
pipelined single-row stream vs the HTTP single-row loop, timed against
the same server in the same run) gates the binary transport's reason to
exist; ``reqs_per_sec_binary_single`` rides the ``--absolute`` tier
like every other absolute rate.

Excluded from ratio gating: ratios against frozen cross-run constants
(``speedup_table_vs_pr1_batch`` divides by a historical constant — an
absolute measurement in disguise), microsecond-scale replay throughputs
(covered by flags), and ``speedup_serve_coalesced_vs_single`` (its
numerator depends on how the host schedules eight client threads —
swings >2x on shared 2-core runners with zero code change; the coalesced
bit-identity flag still gates correctness).

Run:  PYTHONPATH=src python -m benchmarks.check_regression
      PYTHONPATH=src python -m benchmarks.check_regression --suite serve
      PYTHONPATH=src python -m benchmarks.check_regression --absolute
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys

_ROOT = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), ".."))

#: suite name -> (bench module, committed baseline, keys excluded from
#: ratio gating)
SUITES = {
    "sweep": ("benchmarks.sweep_bench",
              os.path.join(_ROOT, "BENCH_sweep.json"),
              ("speedup_table_vs_pr1_batch",
               "configs_per_sec_table_cached")),
    "serve": ("benchmarks.serve_bench",
              os.path.join(_ROOT, "BENCH_serve.json"),
              ("speedup_serve_coalesced_vs_single",)),
}


def _gated_keys(absolute: bool, excluded):
    prefixes = ("configs_per_sec", "reqs_per_sec", "speedup") \
        if absolute else ("speedup",)

    def gated(key):
        return key.startswith(prefixes) and key not in excluded
    return gated


def correctness_failures(fresh: dict, baseline: dict = ()):
    """Every boolean field (and dict-of-boolean field) must be true —
    and every boolean the committed baseline carries must still be
    *present* in the fresh run.  Without the presence check, deleting a
    bit-identity flag from a bench would silently drop its gate; a
    renamed or removed flag must show up here as ``missing``."""
    failures = []
    for key, v in fresh.items():
        if isinstance(v, bool):
            if not v:
                failures.append(key)
        elif isinstance(v, dict) and v and all(
                isinstance(x, bool) for x in v.values()):
            failures.extend(f"{key}[{sub}]"
                            for sub, ok in v.items() if not ok)
    for key, v in dict(baseline).items():
        if isinstance(v, bool) and not isinstance(fresh.get(key), bool):
            failures.append(f"{key} (missing from fresh run)")
    return failures


def compared_flags(fresh: dict, baseline: dict = ()):
    """The correctness-flag names a run was gated on (fresh plus any the
    baseline pins) — printed on PASS so a green run shows what it
    actually checked, not just that nothing failed."""
    flags = set()
    for src in (fresh, dict(baseline)):
        for key, v in src.items():
            if isinstance(v, bool):
                flags.add(key)
            elif isinstance(v, dict) and v and all(
                    isinstance(x, bool) for x in v.values()):
                flags.update(f"{key}[{sub}]" for sub in v)
    return sorted(flags)


def compare(fresh: dict, baseline: dict, tolerance: float, *,
            absolute: bool = False, excluded=()):
    """Return (regressions, correctness_failures) for the two runs."""
    gated = _gated_keys(absolute, excluded)
    regressions = []
    for key, base_val in baseline.items():
        if not gated(key):
            continue
        got = fresh.get(key)
        if got is None or got < base_val * (1.0 - tolerance):
            regressions.append((key, base_val, got))
    return regressions, correctness_failures(fresh, baseline)


def merge_best(attempts):
    """Fieldwise best across runs: max for numbers, OR for booleans (the
    correctness flags are within-run comparisons and flake the same way)."""
    best = dict(attempts[0])
    for run in attempts[1:]:
        for key, v in run.items():
            if isinstance(v, bool):
                best[key] = best.get(key, False) or v
            elif isinstance(v, dict):
                best[key] = {k: best.get(key, {}).get(k, False) or ok
                             for k, ok in v.items()}
            elif isinstance(v, (int, float)):
                best[key] = max(best.get(key, v), v)
    return best


def run_suite(name: str, tolerance: float, attempts: int, *,
              absolute: bool = False, baseline_path=None) -> bool:
    module_name, default_baseline, excluded = SUITES[name]
    path = baseline_path or default_baseline
    with open(path) as f:
        baseline = json.load(f)

    run_bench = importlib.import_module(module_name).run_bench
    runs = []
    fresh = {}
    regressions, failures = [], []
    for i in range(max(attempts, 1)):
        runs.append(run_bench())
        fresh = merge_best(runs)
        regressions, failures = compare(fresh, baseline, tolerance,
                                        absolute=absolute,
                                        excluded=excluded)
        if not regressions and not failures:
            break
        if i + 1 < max(attempts, 1):
            print(f"[{name}] attempt {i + 1}/{attempts}: "
                  f"{len(regressions)} field(s) below tolerance, "
                  f"{len(failures)} flag failure(s), retrying")

    gated = _gated_keys(absolute, excluded)
    width = max((len(k) for k in baseline if gated(k)), default=20)
    for key in sorted(baseline):
        if not gated(key):
            continue
        got = fresh.get(key, float("nan"))
        ratio = got / baseline[key] if baseline[key] else float("inf")
        flag = "REGRESSED" if any(k == key for k, _, _ in regressions) \
            else "ok"
        print(f"[{name}] {key:{width}s}  baseline {baseline[key]:14.1f}  "
              f"fresh {got:14.1f}  ({ratio:5.2f}x)  {flag}")
    for key in failures:
        print(f"[{name}] correctness flag failed: {key}")

    if regressions or failures:
        print(f"[{name}] FAIL: {len(regressions)} regression(s) "
              f"(> {tolerance:.0%} drop), "
              f"{len(failures)} correctness failure(s)")
        return False
    gated_keys = sorted(k for k in baseline if gated(k))
    flags = compared_flags(fresh, baseline)
    print(f"[{name}] PASS: no gated field dropped more than "
          f"{tolerance:.0%} vs {path}")
    print(f"[{name}] compared {len(gated_keys)} gated field(s): "
          f"{', '.join(gated_keys)}")
    print(f"[{name}] compared {len(flags)} correctness flag(s): "
          f"{', '.join(flags) or '(none)'}")
    return True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", default="all",
                    choices=("all", *SUITES),
                    help="which bench suite(s) to gate")
    ap.add_argument("--baseline", default=None,
                    help="override the committed baseline json "
                         "(single-suite runs only)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional drop (0.2 = 20%%)")
    ap.add_argument("--attempts", type=int, default=3,
                    help="max bench reruns; the gate takes the best "
                         "observation per field (noise never speeds a run "
                         "up, so a real regression fails every attempt)")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate absolute configs_per_sec_* / "
                         "reqs_per_sec_* fields (same-machine, idle-host "
                         "runs only)")
    args = ap.parse_args()

    names = list(SUITES) if args.suite == "all" else [args.suite]
    if args.baseline and len(names) > 1:
        ap.error("--baseline requires --suite sweep or --suite serve")

    ok = True
    for name in names:
        ok = run_suite(name, args.tolerance, args.attempts,
                       absolute=args.absolute,
                       baseline_path=args.baseline) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""SweepEngine microbenchmark: 1,000-point matmul tile sweep + a 1M-point
streamed lattice.

Measures configs/sec for the paper's headline pricing workflow (§IV-B
adaptive tile selection: price candidates, return argmin) six ways:

  scalar_predict_loop   looped ``predict.predict`` (the shipped scalar
                        entry point), cold engine — the pre-batching way a
                        consumer priced a sweep
  scalar_model_loop     looped architecture model function
                        (``blackwell.predict``) — the raw scalar model
                        without any engine machinery
  batch                 one ``SweepEngine.predict_batch`` over a Workload
                        list (cache off): the PR 1 vectorized path
  batch_cached_replay   ``predict_batch`` again on a warm cache — served
                        by the whole-batch digest tier, must be strictly
                        FASTER than the cold batch
  table                 one columnar ``predict_table`` over a
                        ``WorkloadTable`` built by ``tile_lattice`` (cache
                        off): no per-config Workloads, no per-config rows
  table_cached_replay   ``predict_table`` again on a warm cache — one
                        content-token hit

Construction cost is measured separately (``workload_build_s`` vs
``table_build_s``): the table path removes the per-config dataclass
construction that dominated the old end-to-end sweep.

The big section prices a ``BIG_N``-row (~1M) lazy ``LatticeSpec`` three
ways, end to end (lattice build + pricing + argmin):

  big_table     materialize the whole table, then fused ``argmin_table``
                — the PR 2 single-core way, peak memory O(n)
  big_stream    ``argmin_stream`` chunk by chunk — peak memory O(chunk),
                and faster than big_table because LLC-resident chunks skip
                the per-column DRAM round-trips of a 200+ MB table
  big_parallel  ``argmin_stream(jobs=auto)`` — chunk shards priced across
                a worker-process pool (``core.parallel``), partial argmins
                merged in the parent

plus tracemalloc peak-memory for the table vs stream paths and
bit-identity of all three winners.

Emits BENCH_sweep.json next to this file; headline criteria:
``speedup_table_vs_pr1_batch >= 3`` (table throughput vs the committed
PR 1 ``configs_per_sec_batch`` baseline), ``cached_faster_than_cold``,
argmin winners bit-identical to a full materialization on all five
routes, streamed reductions bit-identical to fused table reductions on
all five routes, and ``speedup_parallel_vs_table >= 1.5`` at >= 1M
configs.

Run:  PYTHONPATH=src python -m benchmarks.sweep_bench
(benchmarks/check_regression.py wraps this as a CI gate.)
"""
from __future__ import annotations

import json
import os
import resource
import sys
import time
import tracemalloc

import numpy as np

from repro.core import blackwell, hardware, predict as predict_mod, sweep
from repro.core.workload import LatticeSpec, TileConfig, WorkloadTable, \
    gemm_workload, nvec_matrix

N_POINTS = 1000
HW_TARGETS = ("b200", "h200", "mi300a", "mi250x", "tpu_v5e")

#: committed PR 1 batch throughput (BENCH_sweep.json as of PR 1, on the
#: original baseline host) — reported as historical context only; the
#: pass/fail >=3x criterion uses the PR 1 batch path re-measured in the
#: same run (``speedup_table_vs_batch``) so it is machine-independent.
PR1_CONFIGS_PER_SEC_BATCH = 739_132.0

SHAPES = [(4096 + 512 * s, 4096, 4096) for s in range(16)]
TILES = [TileConfig(bm, bn, bk)
         for bm in (64, 128, 256, 512)
         for bn in (64, 128, 256, 512)
         for bk in (16, 32, 64, 128)]

#: route -> hardware target it is valid on (for the argmin parity sweep)
ROUTE_HW = {"stage": "b200", "wavefront": "mi300a", "tpu": "tpu_v5e",
            "generic": "b200", "roofline": "b200"}


def tile_sweep(n: int = N_POINTS):
    """n-point (tile x shape) matmul sweep, fp16, as a Workload list (the
    PR 1 consumer shape: one dataclass per config)."""
    ws = []
    i = 0
    for tile in TILES:
        for m, nn, k in SHAPES:
            ws.append(gemm_workload(f"gemm_{i}", m, nn, k, precision="fp16",
                                    tile=tile))
            i += 1
    return ws[:n]


def tile_table(n: int = N_POINTS) -> WorkloadTable:
    """The same n-point sweep as a columnar WorkloadTable: one
    ``tile_lattice`` per GEMM shape, stacked and reordered to match
    ``tile_sweep`` row-for-row — zero per-config Workloads."""
    parts = [WorkloadTable.tile_lattice(
        gemm_workload(f"base_{j}", m, nn, k, precision="fp16"), TILES)
        for j, (m, nn, k) in enumerate(SHAPES)]
    table = WorkloadTable.concat(parts)
    # concat is shape-major; tile_sweep is tile-major — reorder + truncate
    order = np.arange(len(table)).reshape(len(SHAPES), len(TILES))
    return table.take(order.T.ravel()[:n])


def _interleaved_best(timers: dict, rounds: int = 8) -> dict:
    """Min time per labeled thunk, measured round-robin.

    Shared/throttled hosts shift speed regimes on a seconds scale; timing
    each path in its own contiguous window skews every cross-path ratio by
    whatever regime it happened to land in.  Interleaving samples every
    path across the same overall window, so the per-path minima (and hence
    the speedup_* ratios the regression gate keys on) stay comparable.
    """
    best = {k: float("inf") for k in timers}
    for _ in range(rounds):
        for k, fn in timers.items():
            t0 = time.perf_counter()
            fn()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _argmin_parity(ws) -> dict:
    """argmin_table winner vs full-materialization argmin, per route."""
    out = {}
    table = WorkloadTable.from_workloads(ws)
    for route, hw_name in ROUTE_HW.items():
        hw = hardware.get(hw_name)
        win = sweep.argmin_table(table, hw, model=route,
                                 engine=sweep.SweepEngine(use_cache=False))
        full = list(sweep.SweepEngine(use_cache=False).predict_batch(
            ws, hw, model=route))
        ref_i = min(range(len(full)), key=lambda i: full[i].total)
        ref = full[ref_i]
        out[route] = bool(win.index == ref_i
                          and win.breakdown == ref
                          and win.breakdown.detail == ref.detail)
    return out


def _same_winners(a, b) -> bool:
    a = a if isinstance(a, list) else [a]
    b = b if isinstance(b, list) else [b]
    return (len(a) == len(b)
            and all(x.index == y.index and x.total == y.total
                    and x.name == y.name and x.breakdown == y.breakdown
                    and x.breakdown.detail == y.breakdown.detail
                    for x, y in zip(a, b)))


def _stream_parity(ws, chunk_size: int = 96) -> dict:
    """Streamed argmin/topk/pareto vs the fused table reductions, per
    route, with a chunk size that forces many chunk boundaries."""
    out = {}
    table = WorkloadTable.from_workloads(ws)
    for route, hw_name in ROUTE_HW.items():
        hw = hardware.get(hw_name)
        eng = sweep.SweepEngine(use_cache=False)
        ok = _same_winners(
            sweep.argmin_stream(table, hw, model=route, engine=eng,
                                chunk_size=chunk_size),
            sweep.argmin_table(table, hw, model=route, engine=eng))
        ok = ok and _same_winners(
            sweep.topk_stream(table, hw, 10, model=route, engine=eng,
                              chunk_size=chunk_size),
            sweep.topk_table(table, hw, 10, model=route, engine=eng))
        ok = ok and _same_winners(
            sweep.pareto_stream(table, hw, model=route, engine=eng,
                                chunk_size=chunk_size),
            sweep.pareto_table(table, hw, model=route, engine=eng))
        out[route] = bool(ok)
    return out


# ---------------------------------------------------------------------------
# Big section: ~1M-config lazy lattice, streamed and sharded.
# ---------------------------------------------------------------------------

BIG_N = 1_048_576


def big_lattice() -> LatticeSpec:
    """64 x 64 x 16 x 16 cartesian occupancy grid over an 8192^3 fp16 GEMM
    (every row keeps the tiled-GEMM route on the stage model)."""
    base = gemm_workload("big", 8192, 8192, 8192, precision="fp16")
    return LatticeSpec.cartesian(
        base,
        k_tiles=[8 + 4 * i for i in range(64)],
        num_ctas=[32 + 8 * i for i in range(64)],
        tma_participants=[1, 2, 4, 8] * 4,
        concurrent_kernels=[1, 2] * 8)


def _traced_peak(fn) -> float:
    """tracemalloc peak (MB) across one call — NumPy buffers included."""
    tracemalloc.start()
    try:
        fn()
        return tracemalloc.get_traced_memory()[1] / 1e6
    finally:
        tracemalloc.stop()


def run_big_bench(rounds: int = 3) -> dict:
    spec = big_lattice()
    hw = hardware.B200
    n = len(spec)

    def table_path():
        return sweep.argmin_table(spec.materialize(), hw,
                                  engine=sweep.SweepEngine(use_cache=False))

    def stream_path():
        return sweep.argmin_stream(spec, hw)

    def parallel_path():
        return sweep.argmin_stream(spec, hw, jobs=0)

    win_table = table_path()        # warm + parity reference
    win_stream = stream_path()
    win_parallel = parallel_path()

    t = _interleaved_best({"table": table_path, "stream": stream_path,
                           "parallel": parallel_path}, rounds=rounds)

    peak_table = _traced_peak(table_path)
    peak_stream = _traced_peak(stream_path)

    return {
        "big_n_configs": n,
        "big_table_s": t["table"],
        "big_stream_s": t["stream"],
        "big_parallel_s": t["parallel"],
        "configs_per_sec_big_table": n / t["table"],
        "configs_per_sec_big_stream": n / t["stream"],
        "configs_per_sec_big_parallel": n / t["parallel"],
        "speedup_stream_vs_table": t["table"] / t["stream"],
        "speedup_parallel_vs_table": t["table"] / t["parallel"],
        "peak_mb_big_table": peak_table,
        "peak_mb_big_stream": peak_stream,
        # ru_maxrss is kilobytes on Linux, bytes on macOS
        "ru_maxrss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        / (1024.0 ** 2 if sys.platform == "darwin" else 1024.0),
        "big_stream_bit_identical": _same_winners(win_stream, win_table),
        "big_parallel_bit_identical": _same_winners(win_parallel,
                                                    win_table),
        "stream_peak_bounded": bool(peak_stream < peak_table / 4.0),
    }


def run_bench(n_points: int = N_POINTS) -> dict:
    ws = tile_sweep(n_points)
    hw = hardware.B200
    n = len(ws)

    # warm imports / numpy / hw token outside the timed regions
    predict_mod.predict(ws[0], hw)

    def scalar_predict_loop():
        sweep.default_engine().clear_cache()
        return [predict_mod.predict(w, hw).total for w in ws]

    def scalar_model_loop():
        return [blackwell.predict(w, hw).total for w in ws]

    table = tile_table(n_points)
    # honesty check: the lattice prices exactly the same configurations as
    # the Workload list, row for row
    same_configs = bool(np.array_equal(nvec_matrix(ws), table.cols))

    nocache = sweep.SweepEngine(use_cache=False)
    nocache.predict_batch(ws[:64], hw)          # warm the vectorized path
    cached = sweep.SweepEngine()
    cached.predict_batch(ws, hw)                # populate both tiers
    cached.predict_table(table, hw)

    t = _interleaved_best({
        "pred": scalar_predict_loop,
        "model": scalar_model_loop,
        "build_ws": lambda: tile_sweep(n_points),
        "build_table": lambda: tile_table(n_points),
        "batch": lambda: nocache.predict_batch(ws, hw).totals,
        "table": lambda: nocache.predict_table(table, hw).totals,
        "replay": lambda: cached.predict_batch(ws, hw).totals,
        "treplay": lambda: cached.predict_table(table, hw).totals,
    })
    t_pred, t_model = t["pred"], t["model"]
    t_build_ws, t_build_table = t["build_ws"], t["build_table"]
    t_batch, t_table = t["batch"], t["table"]
    t_replay, t_treplay = t["replay"], t["treplay"]

    # batch-of-1 bit-identity vs the scalar path on every registered target
    parity = {}
    for name in HW_TARGETS:
        target = hardware.get(name)
        w = ws[0]
        one = sweep.SweepEngine().predict_batch([w], target)[0]
        ref = predict_mod.predict(w, target)
        parity[name] = bool(one == ref and one.detail == ref.detail)

    argmin_parity = _argmin_parity(ws)
    stream_parity = _stream_parity(ws)

    row = {
        "n_configs": n,
        "scalar_predict_loop_s": t_pred,
        "scalar_model_loop_s": t_model,
        "batch_s": t_batch,
        "batch_cached_replay_s": t_replay,
        "table_s": t_table,
        "table_cached_replay_s": t_treplay,
        "workload_build_s": t_build_ws,
        "table_build_s": t_build_table,
        "configs_per_sec_scalar_predict": n / t_pred,
        "configs_per_sec_scalar_model": n / t_model,
        "configs_per_sec_batch": n / t_batch,
        "configs_per_sec_cached": n / t_replay,
        "configs_per_sec_table": n / t_table,
        "configs_per_sec_table_cached": n / t_treplay,
        "speedup_vs_scalar_predict": t_pred / t_batch,
        "speedup_vs_scalar_model": t_model / t_batch,
        "cached_speedup_vs_scalar_predict": t_pred / t_replay,
        "speedup_table_vs_batch": t_batch / t_table,
        "speedup_table_vs_pr1_batch": (n / t_table)
        / PR1_CONFIGS_PER_SEC_BATCH,
        "cached_faster_than_cold": bool(t_replay < t_batch),
        "table_cached_faster_than_cold": bool(t_treplay < t_table),
        "table_same_configs_as_list": same_configs,
        "bit_identical_batch_of_1": parity,
        "argmin_table_bit_identical": argmin_parity,
        "stream_reduction_bit_identical": stream_parity,
    }
    row.update(run_big_bench())
    return row


def main() -> None:
    row = run_bench()
    n = row["n_configs"]
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "BENCH_sweep.json")
    with open(os.path.normpath(out), "w") as f:
        json.dump(row, f, indent=1)

    def line(label, t, extra=""):
        print(f"{label:22s}: {t * 1e3:8.2f} ms ({n / t:10.0f} cfg/s){extra}")

    print(f"n = {n} configs (matmul tile sweep, b200 stage model)")
    line("scalar predict() loop", row["scalar_predict_loop_s"])
    line("scalar model-fn loop", row["scalar_model_loop_s"])
    line("predict_batch", row["batch_s"],
         f"  {row['speedup_vs_scalar_predict']:5.1f}x vs predict loop")
    line("batch cached replay", row["batch_cached_replay_s"],
         f"  faster than cold: {row['cached_faster_than_cold']}")
    line("predict_table", row["table_s"],
         f"  {row['speedup_table_vs_batch']:5.2f}x vs batch, "
         f"{row['speedup_table_vs_pr1_batch']:5.2f}x vs PR1 batch")
    line("table cached replay", row["table_cached_replay_s"])
    print(f"build: {row['workload_build_s'] * 1e3:.2f} ms Workload list vs "
          f"{row['table_build_s'] * 1e3:.2f} ms WorkloadTable "
          f"({row['workload_build_s'] / row['table_build_s']:.1f}x)")
    print(f"bit-identical batch-of-1: {row['bit_identical_batch_of_1']}")
    print(f"argmin_table bit-identical: {row['argmin_table_bit_identical']}")
    print(f"stream reductions bit-identical: "
          f"{row['stream_reduction_bit_identical']}")
    bn = row["big_n_configs"]
    print(f"\nbig lattice: n = {bn} configs (lazy cartesian, b200 stage)")
    for key, label in (("big_table_s", "materialize + argmin_table"),
                       ("big_stream_s", "argmin_stream"),
                       ("big_parallel_s", "argmin_stream jobs=auto")):
        t_big = row[key]
        print(f"{label:26s}: {t_big * 1e3:8.1f} ms "
              f"({bn / t_big:10.0f} cfg/s)")
    print(f"stream {row['speedup_stream_vs_table']:.2f}x / parallel "
          f"{row['speedup_parallel_vs_table']:.2f}x vs single-core table; "
          f"peak {row['peak_mb_big_stream']:.1f} MB streamed vs "
          f"{row['peak_mb_big_table']:.1f} MB materialized")
    # >=3x is judged against the PR 1 batch path measured IN THIS RUN
    # (predict_batch is that path, unchanged in role) — the frozen PR 1
    # constant ratio is reported for context but absolute cross-machine
    # throughput is not a pass/fail signal.
    ok = (row["speedup_vs_scalar_predict"] >= 10
          and row["speedup_table_vs_batch"] >= 3
          and row["cached_faster_than_cold"]
          and row["table_cached_faster_than_cold"]
          and row["table_same_configs_as_list"]
          and all(row["bit_identical_batch_of_1"].values())
          and all(row["argmin_table_bit_identical"].values())
          and all(row["stream_reduction_bit_identical"].values())
          and row["big_stream_bit_identical"]
          and row["big_parallel_bit_identical"]
          and row["stream_peak_bounded"]
          and row["speedup_parallel_vs_table"] >= 1.5)
    print("PASS (>=10x scalar, >=3x table-vs-batch, cached<cold, "
          "bit-identical, >=1.5x sharded-vs-table @1M, O(chunk) memory)"
          if ok else "FAIL")


if __name__ == "__main__":
    main()

"""SweepEngine microbenchmark: 1,000-point matmul tile sweep.

Measures configs/sec for the paper's headline pricing workflow (§IV-B
adaptive tile selection: price candidates, return argmin) four ways:

  scalar_predict_loop   looped ``predict.predict`` (the shipped scalar
                        entry point), cold engine — the pre-batching way a
                        consumer priced a sweep
  scalar_model_loop     looped architecture model function
                        (``blackwell.predict``) — the raw scalar model
                        without any engine machinery
  batch                 one ``SweepEngine.predict_batch`` (cache off):
                        the vectorized path
  batch_cached_replay   ``predict_batch`` again on a warm cache —
                        repeated autotune/hillclimb queries

Emits BENCH_sweep.json next to this file; headline criterion:
``speedup_vs_scalar_predict >= 10`` with bit-identical results (checked
here batch-of-1 per hardware target, exhaustively in tests/test_sweep.py).

Run:  PYTHONPATH=src python -m benchmarks.sweep_bench
"""
from __future__ import annotations

import json
import os
import time

from repro.core import blackwell, hardware, predict as predict_mod, sweep
from repro.core.workload import TileConfig, gemm_workload

N_POINTS = 1000
HW_TARGETS = ("b200", "h200", "mi300a", "mi250x", "tpu_v5e")


def tile_sweep(n: int = N_POINTS):
    """n-point (tile x shape) matmul sweep, fp16."""
    ws = []
    shapes = [(4096 + 512 * s, 4096, 4096) for s in range(16)]
    i = 0
    for bm in (64, 128, 256, 512):
        for bn in (64, 128, 256, 512):
            for bk in (16, 32, 64, 128):
                for m, nn, k in shapes:
                    ws.append(gemm_workload(
                        f"gemm_{i}", m, nn, k, precision="fp16",
                        tile=TileConfig(bm, bn, bk)))
                    i += 1
    return ws[:n]


def _best_of(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ws = tile_sweep()
    hw = hardware.B200
    n = len(ws)

    # warm imports / numpy / hw token outside the timed regions
    predict_mod.predict(ws[0], hw)

    def scalar_predict_loop():
        sweep.default_engine().clear_cache()
        return [predict_mod.predict(w, hw).total for w in ws]

    def scalar_model_loop():
        return [blackwell.predict(w, hw).total for w in ws]

    t_pred = _best_of(scalar_predict_loop)
    t_model = _best_of(scalar_model_loop)

    nocache = sweep.SweepEngine(use_cache=False)
    nocache.predict_batch(ws[:64], hw)          # warm the vectorized path
    t_batch = _best_of(lambda: nocache.predict_batch(ws, hw).totals)

    cached = sweep.SweepEngine()
    cached.predict_batch(ws, hw)                # populate
    t_replay = _best_of(lambda: cached.predict_batch(ws, hw).totals)

    # batch-of-1 bit-identity vs the scalar path on every registered target
    parity = {}
    for name in HW_TARGETS:
        target = hardware.get(name)
        w = ws[0]
        one = sweep.SweepEngine().predict_batch([w], target)[0]
        ref = predict_mod.predict(w, target)
        parity[name] = bool(one == ref and one.detail == ref.detail)

    row = {
        "n_configs": n,
        "scalar_predict_loop_s": t_pred,
        "scalar_model_loop_s": t_model,
        "batch_s": t_batch,
        "batch_cached_replay_s": t_replay,
        "configs_per_sec_scalar_predict": n / t_pred,
        "configs_per_sec_scalar_model": n / t_model,
        "configs_per_sec_batch": n / t_batch,
        "configs_per_sec_cached": n / t_replay,
        "speedup_vs_scalar_predict": t_pred / t_batch,
        "speedup_vs_scalar_model": t_model / t_batch,
        "cached_speedup_vs_scalar_predict": t_pred / t_replay,
        "bit_identical_batch_of_1": parity,
    }
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "BENCH_sweep.json")
    with open(os.path.normpath(out), "w") as f:
        json.dump(row, f, indent=1)

    print(f"n = {n} configs (matmul tile sweep, b200 stage model)")
    print(f"scalar predict() loop : {t_pred * 1e3:8.2f} ms "
          f"({n / t_pred:10.0f} cfg/s)")
    print(f"scalar model-fn loop  : {t_model * 1e3:8.2f} ms "
          f"({n / t_model:10.0f} cfg/s)")
    print(f"predict_batch         : {t_batch * 1e3:8.2f} ms "
          f"({n / t_batch:10.0f} cfg/s)  "
          f"{t_pred / t_batch:5.1f}x vs predict loop, "
          f"{t_model / t_batch:4.1f}x vs model-fn loop")
    print(f"cached replay         : {t_replay * 1e3:8.2f} ms "
          f"({n / t_replay:10.0f} cfg/s)")
    print(f"bit-identical batch-of-1: {parity}")
    ok = row["speedup_vs_scalar_predict"] >= 10 and all(parity.values())
    print("PASS (>=10x, bit-identical)" if ok else "FAIL")


if __name__ == "__main__":
    main()

"""One function per paper table/figure.  Each returns (rows, derived) where
rows are CSV-able dicts; run.py prints ``name,us_per_call,derived``.

Tables:
  table_ii_vii   hardware parameter files (peak vs sustained, per platform)
  table_vi       microbenchmark validation MAE per platform vs naive roofline
  table_x        Rodinia 3.1 per-benchmark MAE (B200 + MI300A)
  table_xi       SPEChpc 2021 Tiny per-benchmark MAE
  table_xii      profiler vs first-principles characterization gap
  table_tiles    MI300A occupancy/tile study + adaptive tile selection
  table_2sm      2-SM cooperative speedup prediction
  table_obs1     calibration ladder (uncal -> class-cal -> per-case)
  table_cpuhost  REAL measurements on this container's CPU (methodology
                 replication: microbench -> params -> predict -> MAE)
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core import blackwell, calibrate, cdna3, hardware, roofline, \
    sweep, validate
from repro.core import segments as seg_mod
from repro.core.suites import b200_microbench, mi300a_microbench, ports, \
    rodinia, spechpc, split


def _timeit(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def _batched_pf(ws, hw):
    """Scalar predict-fn for the calibrate.fit_* APIs, backed by ONE
    columnar WorkloadTable query — every subsequent per-workload call
    materializes a row from the table result (identity-matched), falling
    back to the memoized engine for foreign workloads."""
    from repro.core.workload import WorkloadTable
    res = sweep.predict_table(WorkloadTable.from_workloads(ws), hw)
    index = {id(w): i for i, w in enumerate(ws)}

    def pf(w):
        i = index.get(id(w))
        if i is not None:
            return res[i]
        return sweep.default_engine().predict(w, hw)
    return pf


def table_ii_vii() -> Tuple[List[Dict], str]:
    rows = []
    for name in ("b200", "mi300a", "h200", "mi250x", "tpu_v5e"):
        hw = hardware.get(name)
        marquee = {"b200": "fp8", "h200": "fp8", "mi300a": "fp64",
                   "mi250x": "fp64", "tpu_v5e": "bf16"}[name]
        rows.append({
            "platform": name,
            "sms_cus": hw.num_sms,
            "hbm_peak_tbs": hw.hbm_peak_bw / 1e12,
            "hbm_sustained_tbs": hw.hbm_sustained_bw / 1e12,
            "peak_tflops": hw.peak_flops(marquee) / 1e12,
            "sustained_tflops": hw.sustained_flops(marquee) / 1e12,
            "accum_kb": hw.accum_capacity_bytes / 1024,
            "launch_us": hw.launch_latency_s * 1e6,
        })
    return rows, "peak-vs-sustained separation per paper §V-A"


def table_vi() -> Tuple[List[Dict], str]:
    suites = [
        ("b200", hardware.B200, b200_microbench.suite(), 1.33, 96.1),
        ("mi300a", hardware.MI300A, mi300a_microbench.suite(), None, 99.6),
        ("h200", hardware.H200, ports.h200_suite(), 9.57, 94.5),
        ("mi250x", hardware.MI250X, ports.mi250x_suite(), 4.69, 97.9),
    ]
    rows = []
    for name, hw, ents, paper_mae, paper_roof in suites:
        rep = validate.validate_suite(hw, *split(ents))
        rows.append({
            "platform": name, "n": rep.n,
            "model_mae_pct": round(rep.model_mae, 3),
            "roofline_mae_pct": round(rep.roofline_mae, 1),
            "paper_model_mae": paper_mae,
            "paper_roofline_mae": paper_roof,
        })
    # MI300A calibrated row (the ~0.09% headline)
    ws, meas = split(mi300a_microbench.suite())
    pf = _batched_pf(ws, hardware.MI300A)
    cal = calibrate.fit_per_case(ws, meas, pf)
    cal.per_case = {k: round(v, 3) for k, v in cal.per_case.items()}
    rep = validate.validate_suite(hardware.MI300A, ws, meas, calibration=cal)
    rows.append({"platform": "mi300a(calibrated)", "n": rep.n,
                 "model_mae_pct": round(rep.model_mae, 3),
                 "roofline_mae_pct": round(rep.roofline_mae, 1),
                 "paper_model_mae": 0.09, "paper_roofline_mae": 99.6})
    return rows, "model beats naive roofline by >20x on every platform"


def _app_rows(apps_fn, platforms=("b200", "mi300a")) -> List[Dict]:
    rows = []
    for plat in platforms:
        hw = hardware.get(plat)
        for app in apps_fn(plat):
            pred = seg_mod.predict_app(app.name, app.segments, hw)
            seg0 = app.segments[0].workload
            roof = sum(roofline.predict(s.workload, hw).total * s.n_exec
                       for s in app.segments)
            rows.append({
                "platform": plat, "benchmark": app.name,
                "class": app.wclass,
                "measured_ms": round(app.measured_s * 1e3, 4),
                "model_ms": round(pred.total * 1e3, 4),
                "model_mae_pct": round(pred.mae_vs(app.measured_s), 2),
                "paper_mae_pct": app.paper_mae_pct,
                "roofline_mae_pct": round(
                    abs(roof - app.measured_s) / app.measured_s * 100, 1),
                "provenance": app.provenance,
            })
    return rows


def table_x() -> Tuple[List[Dict], str]:
    rows = _app_rows(rodinia.apps)
    sc = [r for r in rows if r["benchmark"] == "streamcluster_1M"
          and r["platform"] == "mi300a"][0]
    derived = (f"streamcluster: measured {sc['measured_ms']:.0f}ms, model "
               f"{sc['model_ms']:.0f}ms, roofline err "
               f"{sc['roofline_mae_pct']:.0f}%")
    return rows, derived


def table_xi() -> Tuple[List[Dict], str]:
    rows = _app_rows(spechpc.apps)
    mi = [r for r in rows if r["platform"] == "mi300a"]
    mae = sum(r["model_mae_pct"] for r in mi) / len(mi)
    return rows, f"MI300A SPEChpc overall MAE {mae:.2f}% (paper 1.3%)"


def table_xii() -> Tuple[List[Dict], str]:
    hw = hardware.MI300A
    fp_segs = spechpc.first_principles_segments()
    rows = []
    for app in spechpc.apps("mi300a"):
        prof = seg_mod.predict_app(app.name, app.segments, hw)
        fp = seg_mod.predict_app(app.name, tuple(fp_segs[app.name]), hw)
        ratio = spechpc.flop_ratios()[app.name]
        rows.append({
            "benchmark": app.name,
            "prof_mae_pct": round(prof.mae_vs(app.measured_s), 2),
            "fp_mae_pct": round(fp.mae_vs(app.measured_s), 2),
            "flop_ratio": ratio,
            "paper_fp_mae": spechpc.TABLE_XI_XII[app.name][4],
        })
    fp_mae = sum(r["fp_mae_pct"] for r in rows) / len(rows)
    return rows, (f"first-principles characterization MAE {fp_mae:.1f}% "
                  "(paper 92.5%): the inputs fail, not the model")


def table_tiles() -> Tuple[List[Dict], str]:
    from repro.core.suites.mi300a_microbench import occupancy_tile_cases
    from repro.core.workload import TileConfig, gemm_workload
    rows = []
    for w in occupancy_tile_cases():
        out = cdna3.occupancy_tile_predict(w, hardware.MI300A)
        rows.append({"case": w.name,
                     "tile": f"{w.tile.bm}x{w.tile.bn}",
                     "predicted_us": round(out.total * 1e6, 3),
                     "w_eff": out.detail["w_eff"]})
    base = gemm_workload("sel", 4096, 4096, 4096, precision="fp32")
    tiles = [TileConfig(s, s, 16) for s in (8, 16, 32, 64)]
    best, costs = cdna3.adaptive_tile_selection(base, hardware.MI300A, tiles)
    return rows, (f"ordering 16x16 < 8x8 preserved; adaptive selection "
                  f"picks {best.bm}x{best.bn}")


def table_2sm() -> Tuple[List[Dict], str]:
    w = b200_microbench.two_sm_case()
    s = blackwell.two_sm_speedup(w, hardware.B200)
    r = blackwell.two_sm_traffic_reduction(w.tile)
    rows = [{"case": "gemm_fp8_16384_2sm",
             "traffic_reduction": round(r, 4),
             "predicted_speedup": round(s, 4),
             "paper_predicted": 1.30, "paper_measured": 1.28}]
    return rows, f"predicted {s:.3f}x vs measured 1.28x (within 2%)"


def table_obs1() -> Tuple[List[Dict], str]:
    """Calibration ladder on MI300A (paper Obs. 1)."""
    ws, meas = split(mi300a_microbench.suite())
    pf = _batched_pf(ws, hardware.MI300A)

    rows = []
    rep0 = validate.validate_suite(hardware.MI300A, ws, meas)
    rows.append({"stage": "uncalibrated", "mae_pct": round(rep0.model_mae, 3),
                 "paper": "5-8%"})
    cal_c, reportc = calibrate.fit_with_holdout(ws, meas, pf, mode="class")
    rows.append({"stage": "class-calibrated(train)",
                 "mae_pct": round(reportc["train_mae"], 3), "paper": "-"})
    rows.append({"stage": "class-calibrated(holdout)",
                 "mae_pct": round(reportc["holdout_mae"], 3), "paper": "-"})
    cal_p = calibrate.fit_per_case(ws, meas, pf)
    cal_p.per_case = {k: round(v, 3) for k, v in cal_p.per_case.items()}
    repp = validate.validate_suite(hardware.MI300A, ws, meas,
                                   calibration=cal_p)
    rows.append({"stage": "per-case-calibrated",
                 "mae_pct": round(repp.model_mae, 3), "paper": "~0.09%"})
    return rows, "calibration ladder reproduces Obs. 1"


def table_cpuhost(quick: bool = True) -> Tuple[List[Dict], str]:
    """The genuinely-measured leg: microbenchmark THIS machine, calibrate,
    predict, validate (paper methodology end-to-end)."""
    from repro.core import microbench
    hw = microbench.calibrate_host(quick=quick)
    ws, meas = microbench.host_suite(quick=quick)
    rep = validate.validate_suite(hw, ws, meas)

    pf = _batched_pf(ws, hw)
    cal, cal_report = calibrate.fit_with_holdout(ws, meas, pf, mode="class")
    cal_p = calibrate.fit_per_case(ws, meas, pf)
    repp = validate.validate_suite(hw, ws, meas, calibration=cal_p)

    rows = [{
        "kernel": r.name, "class": r.wclass,
        "measured_us": round(r.measured_s * 1e6, 1),
        "model_us": round(r.model_s * 1e6, 1),
        "model_err_pct": round(r.model_err, 1),
        "roofline_err_pct": round(r.roofline_err, 1),
    } for r in rep.rows]
    derived = (f"REAL measurements: uncal {rep.model_mae:.0f}% vs roofline "
               f"{rep.roofline_mae:.0f}%; class-cal holdout "
               f"{cal_report['holdout_mae']:.0f}%; per-case "
               f"{repp.model_mae:.2f}%")
    return rows, derived

"""Static contract gate: lint the repo against its standing invariants.

The diff-time sibling of ``check_hwlib`` (runtime hardware-library
invariants) and ``check_regression`` (performance/correctness ratios):

    PYTHONPATH=src python -m benchmarks.check_contracts

Exit 0 when every error-severity finding is suppressed with a
justification; non-zero otherwise.  ``--json`` emits the full machine-
readable report, ``--baseline FILE`` grandfathers a previous report's
findings (adopting the gate on a repo with known debt), and
``--update-wire-lock`` regenerates ``wire_schema.lock.json`` from the
current codec/framing source after a reviewed wire change.

Rules, suppression syntax (``# repro: allow[RULE-ID] <why>``), and how
to add a rule: ``src/repro/analysis/README.md``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro import analysis
from repro.analysis.rules import wire_drift


def check(verbose: bool = True,
          root: Optional[str] = None,
          rules: Optional[List[str]] = None,
          baseline: Optional[str] = None) -> List[str]:
    """Run the linter; returns one rendered line-group per unsuppressed
    finding (errors and warnings)."""
    report = analysis.run_checks(root=root, rules=rules, baseline=baseline)
    problems = [f.render() for f in report.unsuppressed()]
    if verbose:
        suppressed = sum(1 for f in report.findings if f.suppressed)
        for line in problems:
            print(line)
        print(f"check_contracts: {len(report.errors)} error(s), "
              f"{len(report.unsuppressed(analysis.WARNING))} warning(s), "
              f"{suppressed} suppressed")
    return [f.render() for f in report.errors]


def _update_wire_lock(root: Optional[str]) -> int:
    import os

    root = os.path.abspath(root or analysis.repo_root())
    modules = analysis.core.collect_modules(root, analysis.DEFAULT_PATHS)
    project = analysis.Project(root, modules)
    schema, _ = wire_drift.extract_schema(project)
    path = wire_drift.write_lock(root, schema)
    print(f"wire schema lock written: {path}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="check_contracts",
        description="AST-based gate for the repo's standing contracts")
    ap.add_argument("--root", default=None,
                    help="repo root to lint (default: this checkout)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="ID",
                    help="run only this rule id (repeatable)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="JSON report whose findings are grandfathered")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report on stdout")
    ap.add_argument("--update-wire-lock", action="store_true",
                    help="regenerate wire_schema.lock.json from source "
                         "after a reviewed wire change")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print only the verdict")
    args = ap.parse_args(argv)

    if args.update_wire_lock:
        return _update_wire_lock(args.root)

    report = analysis.run_checks(
        root=args.root, rules=args.rules, baseline=args.baseline)
    if args.json:
        print(json.dumps(report.to_json(), indent=2))
    elif not args.quiet:
        rendered = report.render(verbose=False)
        if rendered:
            print(rendered)

    n_err = len(report.errors)
    n_warn = len(report.unsuppressed(analysis.WARNING))
    n_supp = sum(1 for f in report.findings if f.suppressed)
    verdict = "PASS" if report.ok else "FAIL"
    line = (f"check_contracts: {verdict} — {n_err} error(s), "
            f"{n_warn} warning(s), {n_supp} suppressed")
    if report.ok:
        if not args.json:
            print(line)
        return 0
    print(f"FAIL: {line}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())

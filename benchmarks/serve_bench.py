"""Prediction-serving benchmark: wire throughput against a real server.

Spawns ``repro.serve.server`` as a genuine second process (the acceptance
scenario) and measures request throughput over loopback HTTP four ways:

  single_row    N sequential argmin requests, one configuration each —
                the anti-pattern a naive client would write; per-request
                HTTP + codec overhead dominates
  batched       one argmin request carrying the whole table — the
                intended wire shape (one contiguous column matrix)
  coalesced     T client threads firing small-table requests
                concurrently — the server's micro-batching fuses
                same-hardware requests into shared columnar evaluations
  streamed      a ~1M-row lazy ``LatticeSpec`` sent as a tiny plan and
                reduced server-side in O(chunk) memory

plus cold-vs-replay on a 16k-row CDNA3 hit-rate table (the server's
whole-table memo cache answering an identical re-sent sweep; routed so
the saved compute dominates loopback jitter — see ``replay_table``) and
bit-identity flags against the in-process ``argmin_table`` /
``argmin_stream`` answers.

A **binary transport** section measures the same single-row stream over
the length-prefixed persistent-socket protocol (``--binary-port``, see
``serve/README.md`` "Binary framing (v1)"):

  binary sequential  the HTTP single-row loop's shape, reframed — one
                     request/reply round-trip at a time on one
                     persistent socket (no reconnects, no text headers,
                     no Nagle/delayed-ACK stall)
  binary pipelined   all N single-row requests written in one burst
                     with distinct request ids, replies demuxed by id —
                     the transport's intended operating mode; this is
                     the ``reqs_per_sec_binary_single`` headline
  dedup              N pipelined copies of one identical table — the
                     coalescer's cross-request dedup prices the content
                     once and answers every request from its own table
                     (``serve_dedup_requests_saved`` /
                     ``serve_dedup_rows_saved`` counters)

``serve_binary_bit_identical`` / ``serve_dedup_bit_identical`` pin the
binary and deduped answers to the in-process ones, and
``speedup_binary_vs_http_single`` (a within-run ratio, immune to host
speed) is gated by ``check_regression`` alongside the floors.

A **metrics-overhead** section reruns the pipelined binary burst against
two fresh, identically-warmed servers — one ``--metrics on``, one
``--metrics off`` — interleaved best-of-rounds.  The observability layer
(`repro.obs`, see serve/README.md "Observability") bills itself as
near-free; ``serve_metrics_overhead_bounded`` (metrics-on within 5% of
metrics-off) is the auto-gated proof, and
``serve_metrics_overhead_ratio`` records the measured on/off ratio.

An **availability-under-chaos** section replays a fixed request stream
through ``repro.serve.chaos.ChaosProxy`` with a seeded fault barrage
(one stall + a mixed delay/truncate/bitflip/sever schedule): every
request must complete via the client's retry machinery and come back
bit-identical to the in-process answer.  Emitted as
``serve_chaos_all_completed`` / ``serve_chaos_all_correct`` — booleans,
so ``check_regression`` auto-gates them as correctness flags.

Timings are interleaved round-robin and the per-path minima are kept
(same rationale as sweep_bench: shared hosts drift on a seconds scale,
within-run ratios stay comparable).  Emits BENCH_serve.json; gated by
``benchmarks.check_regression`` on ``speedup_serve_batched_vs_single``
(the >=3x acceptance criterion rides on this), ``speedup_serve_replay_vs_
cold`` and every bit-identity flag.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench
"""
from __future__ import annotations

import json
import os
import threading
import time

from repro.core import hardware, sweep
from repro.core.workload import LatticeSpec, TileConfig, WorkloadTable, \
    gemm_workload
from repro.serve.client import PredictionClient
from repro.serve.subproc import (start_server_subprocess as start_server,
                                 stop_server_subprocess as stop_server)

N_SINGLE = 64          #: sequential single-row requests per round
N_DEDUP = 32           #: identical pipelined requests in the dedup pass
COALESCE_THREADS = 8   #: concurrent clients in the coalesced pass
COALESCE_REQS = 8      #: small-table requests per concurrent client
ROUNDS = 5

CHAOS_SEED = 20260807  #: fixed seed -> the fault barrage is reproducible
CHAOS_FAULTS = 12      #: seeded faults after the leading stall
CHAOS_REQS = 16        #: requests replayed through the chaos proxy

TILES = [TileConfig(bm, bn, bk) for bm in (64, 128, 256, 512)
         for bn in (64, 128, 256, 512) for bk in (16, 32, 64, 128)]
SHAPES = [(4096 + 512 * s, 4096, 4096) for s in range(16)]

BIG_N = 1_048_576


def bench_table() -> WorkloadTable:
    """1,024-row (tile x shape) sweep, matching sweep_bench's workload."""
    parts = [WorkloadTable.tile_lattice(
        gemm_workload(f"shape{j}", m, n, k, precision="fp16"), TILES[:64])
        for j, (m, n, k) in enumerate(SHAPES)]
    return WorkloadTable.concat(parts)


def big_lattice() -> LatticeSpec:
    base = gemm_workload("big", 8192, 8192, 8192, precision="fp16")
    return LatticeSpec.cartesian(
        base,
        k_tiles=[8 + 4 * i for i in range(64)],
        num_ctas=[32 + 8 * i for i in range(64)],
        tma_participants=[1, 2, 4, 8] * 4,
        concurrent_kernels=[1, 2] * 8)


def replay_table() -> WorkloadTable:
    """16,384-row CDNA3 hit-rate table for the cold-vs-replay pass.

    The replay gate needs compute >> wire: on the vectorized stage route
    a row costs ~0.2us to price but ~1us to ship+hash, so the memo-cache
    saving would drown in loopback jitter.  Explicit hit-rate rows take
    the wavefront model's scalar latency-walk fallback — the repo's most
    expensive per-row path (~10us/row) — so a cold request costs ~100ms
    more than its memo-cache replay and the ratio is stable."""
    base = gemm_workload("replay", 4096, 4096, 4096, precision="fp16")
    base = base.replace(num_loads=12.0,
                        hit_rates={"h_l1": 0.5, "h_l2": 0.7, "h_llc": 0.9})
    return LatticeSpec.cartesian(
        base,
        k_tiles=[8 + 4 * i for i in range(64)],
        num_ctas=[32 + 8 * i for i in range(64)],
        tma_participants=[1, 2, 4, 8]).materialize()


def _same_winner(a, b) -> bool:
    return bool(a.index == b.index and a.total == b.total
                and a.name == b.name and a.breakdown == b.breakdown
                and a.breakdown.detail == b.breakdown.detail)


def _run_chaos(host: str, port: int, parts, hw) -> dict:
    """Availability under a seeded fault barrage (see module docstring).

    The schedule is finite and the proxy serves ``pass`` once it is
    exhausted, so with ``max_retries`` sized past the schedule every
    request is guaranteed to land eventually — the gate is that each
    one actually does, bit-identically, with no hangs (the stall fault
    is bounded by the client's short read timeout)."""
    from repro.serve.chaos import ChaosProxy, FaultSpec, seeded_schedule

    schedule = [FaultSpec("stall")] + seeded_schedule(CHAOS_SEED,
                                                     CHAOS_FAULTS)
    refs = [sweep.argmin_table(p, hw,
                               engine=sweep.SweepEngine(use_cache=False))
            for p in parts]
    completed = correct = 0
    t0 = time.perf_counter()
    with ChaosProxy(host, port, schedule) as proxy:
        c = PredictionClient(proxy.address[0], proxy.address[1],
                             timeout=2.0, connect_timeout=2.0,
                             max_retries=4 + len(schedule),
                             backoff_base_s=0.01, backoff_cap_s=0.2)
        try:
            for part, ref in zip(parts, refs):
                try:
                    win = c.argmin(part, "b200", coalesce=False)
                except Exception:
                    continue
                finally:
                    # Keep-alive would let one clean connection absorb
                    # the whole stream; a fresh connect per request
                    # marches through the fault schedule instead.
                    c.close()
                completed += 1
                correct += _same_winner(win, ref)
        finally:
            c.close()
        faults = proxy.faults_injected()
    elapsed = time.perf_counter() - t0
    nreq = len(parts)
    return {
        "serve_chaos_requests": nreq,
        "serve_chaos_faults_injected": int(faults),
        "serve_chaos_elapsed_s": elapsed,
        "serve_chaos_completed_fraction": completed / nreq,
        "serve_chaos_all_completed": bool(completed == nreq),
        "serve_chaos_all_correct": bool(correct == nreq),
    }


def _run_metrics_overhead(singles) -> dict:
    """Instrumentation cost of the observability layer on the hot path.

    Two fresh servers, identical except for ``--metrics on|off``, each
    warmed with one pipelined pass (so both answer the timed rounds from
    their memo caches and the measurement is wire + instrumentation, the
    worst case for relative overhead).  Rounds interleave on/off and the
    per-mode minima are kept, same rationale as the main round-robin —
    but with 4x the rounds: each pass is tens of milliseconds, and the
    5% bound is tighter than loopback jitter on a single minimum.
    The gate is ``serve_metrics_overhead_bounded``: metrics-on pipelined
    time within 5% of metrics-off."""
    servers = {}
    best = {"on": float("inf"), "off": float("inf")}
    try:
        for mode in ("on", "off"):
            proc, host, port, bport = start_server(
                ["--jobs", "0", "--metrics", mode], binary=True)
            c = PredictionClient(host, port, binary_port=bport,
                                 timeout=600.0)
            c.health()
            c.argmin_many(singles, "b200")     # warm cache + socket
            servers[mode] = (proc, c)
        for _ in range(ROUNDS * 4):
            for mode in ("on", "off"):
                c = servers[mode][1]
                t0 = time.perf_counter()
                c.argmin_many(singles, "b200")
                best[mode] = min(best[mode], time.perf_counter() - t0)
    finally:
        for proc, c in servers.values():
            c.close()
            stop_server(proc)
    ratio = best["on"] / best["off"]
    return {
        "serve_metrics_on_pipelined_s": best["on"],
        "serve_metrics_off_pipelined_s": best["off"],
        "serve_metrics_overhead_ratio": ratio,
        "serve_metrics_overhead_bounded": bool(ratio <= 1.05),
    }


def run_bench() -> dict:
    table = bench_table()
    n = len(table)
    singles = [table._slice(i, i + 1) for i in range(N_SINGLE)]
    small_parts = [
        table._slice(j * 16, (j + 1) * 16)
        for j in range(COALESCE_THREADS * COALESCE_REQS)]
    spec = big_lattice()
    hw = hardware.B200

    proc, host, port, bport = start_server(["--jobs", "0"], binary=True)
    client = PredictionClient(host, port, timeout=600.0,
                              transport="http")
    bclient = PredictionClient(host, port, binary_port=bport,
                               timeout=600.0)
    try:
        client.health()                       # connection warm-up
        bclient.health()

        # parity references, computed in-process
        ref_win = sweep.argmin_table(table, hw,
                                     engine=sweep.SweepEngine(
                                         use_cache=False))
        got_win = client.argmin(table, "b200")
        batched_ok = _same_winner(got_win, ref_win)

        coalesced_ok = True
        for part in small_parts[:4]:
            ref = sweep.argmin_table(part, hw,
                                     engine=sweep.SweepEngine(
                                         use_cache=False))
            if not _same_winner(client.argmin(part, "b200"), ref):
                coalesced_ok = False

        t0 = time.perf_counter()
        got_stream = client.argmin(spec, "b200")
        t_stream = time.perf_counter() - t0
        stream_ok = _same_winner(got_stream, sweep.argmin_stream(spec, hw))

        rtable = replay_table()
        mi300a = hardware.get("mi300a")
        replay_ok = _same_winner(
            client.argmin(rtable, "mi300a"),
            sweep.argmin_table(rtable, mi300a,
                               engine=sweep.SweepEngine(use_cache=False)))

        # binary parity: the framed socket must answer bit-identically
        # to both the in-process sweep and the HTTP route
        single_refs = [
            sweep.argmin_table(s, hw,
                               engine=sweep.SweepEngine(use_cache=False))
            for s in singles[:8]]
        binary_ok = _same_winner(bclient.argmin(table, "b200"), ref_win)
        for got, ref in zip(bclient.argmin_many(singles[:8], "b200"),
                            single_refs):
            binary_ok = binary_ok and _same_winner(got, ref)

        # cross-request dedup: N pipelined copies of one table price
        # once; every reply must still be the full bit-identical answer
        before = bclient.cache_stats()
        dedup_wins = bclient.argmin_many([table] * N_DEDUP, "b200")
        after = bclient.cache_stats()
        dedup_ok = all(_same_winner(w, ref_win) for w in dedup_wins)
        dedup_reqs_saved = (after["coalescer_deduped_requests"]
                            - before["coalescer_deduped_requests"])
        dedup_rows_saved = (after["coalescer_dedup_rows_saved"]
                            - before["coalescer_dedup_rows_saved"])

        # ---------------------------------------------- timed round-robin
        best = {"single": float("inf"), "batched": float("inf"),
                "coalesced": float("inf"), "cold": float("inf"),
                "replay": float("inf"), "bin_seq": float("inf"),
                "bin_pipe": float("inf")}

        clients = [PredictionClient(host, port, timeout=600.0)
                   for _ in range(COALESCE_THREADS)]

        def run_coalesced() -> None:
            def worker(ci: int) -> None:
                c = clients[ci]
                for r in range(COALESCE_REQS):
                    c.argmin(small_parts[ci * COALESCE_REQS + r], "b200")
            threads = [threading.Thread(target=worker, args=(ci,))
                       for ci in range(COALESCE_THREADS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()

        for _ in range(ROUNDS):
            t0 = time.perf_counter()
            for s in singles:
                client.argmin(s, "b200", coalesce=False)
            best["single"] = min(best["single"],
                                 time.perf_counter() - t0)

            t0 = time.perf_counter()
            for s in singles:
                bclient.argmin(s, "b200", coalesce=False)
            best["bin_seq"] = min(best["bin_seq"],
                                  time.perf_counter() - t0)

            t0 = time.perf_counter()
            bclient.argmin_many(singles, "b200")
            best["bin_pipe"] = min(best["bin_pipe"],
                                   time.perf_counter() - t0)

            t0 = time.perf_counter()
            client.argmin(table, "b200")
            best["batched"] = min(best["batched"],
                                  time.perf_counter() - t0)

            client.clear_cache()
            t0 = time.perf_counter()
            client.argmin(rtable, "mi300a")
            best["cold"] = min(best["cold"], time.perf_counter() - t0)

            t0 = time.perf_counter()
            client.argmin(rtable, "mi300a")
            best["replay"] = min(best["replay"],
                                 time.perf_counter() - t0)

            t0 = time.perf_counter()
            run_coalesced()
            best["coalesced"] = min(best["coalesced"],
                                    time.perf_counter() - t0)

        for c in clients:
            c.close()

        chaos = _run_chaos(host, port, small_parts[:CHAOS_REQS], hw)
        overhead = _run_metrics_overhead(singles)

        stats = client.cache_stats()
        single_cfg_s = N_SINGLE / best["single"]
        bin_seq_req_s = N_SINGLE / best["bin_seq"]
        bin_pipe_req_s = N_SINGLE / best["bin_pipe"]
        batched_cfg_s = n / best["batched"]
        n_coal = sum(len(p) for p in small_parts)
        coal_cfg_s = n_coal / best["coalesced"]
        coal_req_s = (COALESCE_THREADS * COALESCE_REQS) / best["coalesced"]

        return {
            "serve_n_configs": n,
            "serve_replay_n_configs": len(rtable),
            "serve_big_n_configs": spec.n_rows,
            "serve_single_row_s": best["single"],
            "serve_batched_s": best["batched"],
            "serve_cold_s": best["cold"],
            "serve_replay_s": best["replay"],
            "serve_coalesced_s": best["coalesced"],
            "serve_stream_s": t_stream,
            "serve_binary_single_seq_s": best["bin_seq"],
            "serve_binary_pipelined_s": best["bin_pipe"],
            "reqs_per_sec_serve_single": single_cfg_s,
            "reqs_per_sec_serve_coalesced": coal_req_s,
            "reqs_per_sec_binary_single_seq": bin_seq_req_s,
            "reqs_per_sec_binary_single": bin_pipe_req_s,
            "speedup_binary_vs_http_single":
                bin_pipe_req_s / single_cfg_s,
            "speedup_binary_seq_vs_http_single":
                bin_seq_req_s / single_cfg_s,
            "configs_per_sec_serve_single": single_cfg_s,
            "configs_per_sec_serve_batched": batched_cfg_s,
            "configs_per_sec_serve_coalesced": coal_cfg_s,
            "configs_per_sec_serve_stream": spec.n_rows / t_stream,
            "speedup_serve_batched_vs_single":
                batched_cfg_s / single_cfg_s,
            "speedup_serve_coalesced_vs_single":
                coal_cfg_s / single_cfg_s,
            "speedup_serve_replay_vs_cold": best["cold"] / best["replay"],
            "serve_batched_bit_identical": batched_ok,
            "serve_binary_bit_identical": binary_ok,
            "serve_dedup_bit_identical": dedup_ok,
            "serve_dedup_requests_saved": int(dedup_reqs_saved),
            "serve_dedup_rows_saved": int(dedup_rows_saved),
            "serve_binary_no_protocol_errors": bool(
                stats.get("binary_protocol_errors", 0) == 0),
            "serve_replay_bit_identical": replay_ok,
            "serve_coalesced_bit_identical": coalesced_ok,
            "serve_stream_bit_identical": stream_ok,
            "serve_replay_not_slower": bool(best["replay"]
                                            <= best["cold"]),
            "serve_coalesced_requests_fused": int(
                stats.get("coalescer_coalesced_requests", 0)),
            **chaos,
            **overhead,
        }
    finally:
        client.close()
        bclient.close()
        stop_server(proc)


def main() -> None:
    row = run_bench()
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "BENCH_serve.json")
    with open(os.path.normpath(out), "w") as f:
        json.dump(row, f, indent=1)

    n = row["serve_n_configs"]
    print(f"served sweep: n = {n} configs over loopback HTTP "
          f"(second process, b200 stage model)")
    print(f"single-row loop : {row['serve_single_row_s'] * 1e3:8.1f} ms "
          f"({row['configs_per_sec_serve_single']:10.0f} cfg/s = req/s)")
    print(f"binary seq      : "
          f"{row['serve_binary_single_seq_s'] * 1e3:8.1f} ms "
          f"({row['reqs_per_sec_binary_single_seq']:10.0f} req/s)  "
          f"{row['speedup_binary_seq_vs_http_single']:.1f}x vs HTTP "
          f"single-row")
    print(f"binary pipelined: "
          f"{row['serve_binary_pipelined_s'] * 1e3:8.1f} ms "
          f"({row['reqs_per_sec_binary_single']:10.0f} req/s)  "
          f"{row['speedup_binary_vs_http_single']:.1f}x vs HTTP "
          f"single-row")
    print(f"dedup (x{N_DEDUP})     : "
          f"{row['serve_dedup_requests_saved']} requests deduped, "
          f"{row['serve_dedup_rows_saved']} rows saved, "
          f"bit_identical={row['serve_dedup_bit_identical']}")
    print(f"batched request : {row['serve_batched_s'] * 1e3:8.1f} ms "
          f"({row['configs_per_sec_serve_batched']:10.0f} cfg/s)  "
          f"{row['speedup_serve_batched_vs_single']:.1f}x vs single-row")
    print(f"coalesced (x{COALESCE_THREADS})  : "
          f"{row['serve_coalesced_s'] * 1e3:8.1f} ms "
          f"({row['configs_per_sec_serve_coalesced']:10.0f} cfg/s)  "
          f"{row['speedup_serve_coalesced_vs_single']:.1f}x vs "
          f"single-row, {row['serve_coalesced_requests_fused']} reqs "
          f"fused")
    print(f"cold vs replay  : {row['serve_cold_s'] * 1e3:8.1f} ms -> "
          f"{row['serve_replay_s'] * 1e3:8.1f} ms "
          f"({row['speedup_serve_replay_vs_cold']:.2f}x on "
          f"{row['serve_replay_n_configs']} rows)")
    print(f"streamed lattice: {row['serve_big_n_configs']} rows in "
          f"{row['serve_stream_s']:.2f} s "
          f"({row['configs_per_sec_serve_stream']:10.0f} cfg/s)")
    print(f"bit-identical: batched={row['serve_batched_bit_identical']} "
          f"coalesced={row['serve_coalesced_bit_identical']} "
          f"stream={row['serve_stream_bit_identical']} "
          f"binary={row['serve_binary_bit_identical']}")
    print(f"chaos barrage   : {row['serve_chaos_requests']} reqs, "
          f"{row['serve_chaos_faults_injected']} faults injected, "
          f"{row['serve_chaos_completed_fraction'] * 100:.0f}% completed "
          f"in {row['serve_chaos_elapsed_s']:.2f} s, "
          f"all_correct={row['serve_chaos_all_correct']}")
    print(f"metrics overhead: on "
          f"{row['serve_metrics_on_pipelined_s'] * 1e3:8.1f} ms vs off "
          f"{row['serve_metrics_off_pipelined_s'] * 1e3:8.1f} ms "
          f"pipelined "
          f"({(row['serve_metrics_overhead_ratio'] - 1) * 100:+.1f}%), "
          f"bounded={row['serve_metrics_overhead_bounded']}")
    ok = (row["speedup_serve_batched_vs_single"] >= 3
          and row["speedup_binary_vs_http_single"] >= 10
          and row["serve_batched_bit_identical"]
          and row["serve_coalesced_bit_identical"]
          and row["serve_stream_bit_identical"]
          and row["serve_binary_bit_identical"]
          and row["serve_dedup_bit_identical"]
          and row["serve_replay_not_slower"]
          and row["serve_chaos_all_correct"]
          and row["serve_metrics_overhead_bounded"])
    print("PASS (>=3x batched-vs-single, >=10x binary-vs-http single, "
          "bit-identical, replay<=cold, chaos-correct, metrics<=5%)"
          if ok else "FAIL")


if __name__ == "__main__":
    main()

"""§Perf hillclimbing runner: named (cell, plan-override) experiments,
each re-lowers + re-accounts and prints before/after roofline terms.

    PYTHONPATH=src python -m benchmarks.hillclimb --exp mamba2_shardmap

Every experiment records: hypothesis, napkin-math prediction, change.
Results go into EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import json
import os

EXPERIMENTS = {
    # ---------------- mamba2-1.3b x train_4k (collective-bound) ----------
    "mamba2_shardmap": {
        "cell": ("mamba2-1.3b", "train_4k"),
        "hypothesis": (
            "GSPMD autodiff of the head-block SSD loop emits per-iteration "
            "(B,nc,L,L)-sized backward all-reduces (~1.4e9 B/chip each). "
            "shard_map-ing the SSD leaves only layer-boundary psums for "
            "dB/dC/dA (~0.5 GB global x 48 layers) + FSDP traffic. "
            "Napkin: collective term 5.32s -> ~0.5s (>10x)."),
        "override": {"cfg_overrides": {"ssd_shard_map": True}},
    },
    "mamba2_shardmap_bf16ssd": {
        "cell": ("mamba2-1.3b", "train_4k"),
        "hypothesis": (
            "After shard_map, memory term should dominate; SSD runs in "
            "fp32 (4 B/elem on every (L,L) tile). bf16 params already; "
            "keep fp32 SSD but drop accum dtype to bf16 and raise "
            "microbatches to 16: per-ubatch logits/carries halve. "
            "Napkin: memory term -15-25%."),
        "override": {"cfg_overrides": {"ssd_shard_map": True},
                     "microbatches": 16, "accum_dtype": "bfloat16"},
    },
    "mamba2_bf16_tiles": {
        "cell": ("mamba2-1.3b", "train_4k"),
        "hypothesis": (
            "Memory now dominates (4.49s); the XLA SSD fallback streams "
            "fp32 (L,L) tiles: ~B*S*L*H*4B x ~5 tensors/layer ~ 3.4e14 B "
            "of the 9.4e14 total. bf16 tiles (fp32 accumulation) halve "
            "that share. Napkin: memory term 4.49 -> ~3.6s; NOTE the "
            "Pallas kernel keeps these tiles in VMEM on real TPU, "
            "removing them entirely."),
        "override": {"cfg_overrides": {"ssd_shard_map": True,
                                       "ssd_tile_bf16": True},
                     "microbatches": 16, "accum_dtype": "bfloat16"},
    },
    # ---------------- deepseek-v3-671b x train_4k (worst fraction) -------
    "dsv3_mtp_share": {
        "cell": ("deepseek-v3-671b", "train_4k"),
        "hypothesis": (
            "MTP head re-runs the full 61-layer trunk forward: one extra "
            "fwd = +~33% flops at remat=full (fwd:bwd = 1:2). Sharing the "
            "trunk removes it. Napkin: HLO flops x~0.75, useful 0.25 -> "
            "~0.33; memory term down similarly."),
        "override": {"cfg_overrides": {"mtp_share_trunk": True}},
    },
    "dsv3_mtp_remat_block": {
        "cell": ("deepseek-v3-671b", "train_4k"),
        "hypothesis": (
            "remat=full recomputes the whole block in bwd (5/3 flop "
            "factor); with d_model sharded over 'model', block-level remat "
            "(4/3) fits. Napkin: flops x0.8 on top of MTP sharing; "
            "useful -> ~0.42."),
        "override": {"cfg_overrides": {"mtp_share_trunk": True},
                     "remat": "block"},
    },
    "dsv3_full_stack": {
        "cell": ("deepseek-v3-671b", "train_4k"),
        "hypothesis": (
            "int8 block-quantized Adam moments cut optimizer state from "
            "4 B/param (2x bf16) to ~2.05 B/param: argument bytes "
            "15.8 GB/chip -> ~10.6 GB/chip => the cell finally FITS "
            "single-pod HBM (the baseline's blocker). Terms roughly "
            "unchanged; memory_analysis is the metric."),
        "override": {"cfg_overrides": {"mtp_share_trunk": True},
                     "remat": "block", "moment_dtype": "int8"},
    },
    # ---------------- llama3-405b x train_4k (paper-representative) ------
    "llama405b_remat_block": {
        "cell": ("llama3-405b", "train_4k"),
        "hypothesis": (
            "remat=full pays 5/3 flops; block remat pays 4/3 and the "
            "per-ubatch carries (2.1 GB/chip) still fit. Napkin: compute "
            "term 65.5s -> ~52s, useful 0.77 -> ~0.96."),
        "override": {"remat": "block"},
    },
    "llama405b_unshard_embed": {
        "cell": ("llama3-405b", "train_4k"),
        "hypothesis": (
            "The rules['embed']='model' residual-stream sharding forces "
            "an all-gather of x per layer (fwd+bwd). With remat=block + "
            "microbatches=16 the unsharded carries fit; dropping the rule "
            "removes those gathers. Napkin: collective term down by the "
            "x-gather share (~126 x 134 MB x 3 / step ~ 5e13 B of 1.25e15 "
            "-> small) BUT memory term drops the gather-byte traffic too; "
            "mainly a memory-term test."),
        "override": {"remat": "block", "microbatches": 16,
                     "rules": {"embed": None}},
    },
    "llama405b_q8_u4": {
        "cell": ("llama3-405b", "train_4k"),
        "hypothesis": (
            "int8 moments free 3.2 GB/chip; spend it on microbatches=4 "
            "(fewer FSDP param re-gathers per step: gather volume scales "
            "with ubatch count at remat=block where bwd regathers). "
            "Napkin: collective term -30-50%, fits HBM."),
        "override": {"remat": "block", "microbatches": 4,
                     "moment_dtype": "int8"},
    },
}


def screen(names, json_out: str | None = None, *, jobs=None,
           chunk_size: int | None = None):
    """Napkin-math pre-screen: price every experiment's plan against its
    cell's baseline through ``autotune.enumerate_plans`` (no lowering — a
    full screen costs milliseconds vs minutes per compile).

    Experiments are grouped by cell so each cell's config/param maths is
    computed once; each cell prices all its plans in ONE columnar
    enumerate_plans call — ``opt_state_bytes`` (the int8-moments HBM-fit
    input) is passed per plan.  Kernel-level what-ifs ride the shared
    SweepEngine cache.  Model changes hidden behind ``cfg_overrides``
    (e.g. shard_map SSD) are not visible to the analytical plan model and
    are marked as such.

    ``jobs``/``chunk_size`` thread through to the sharded plan executor
    (``--jobs``/``--chunk-size``; auto-sized pools only engage once a cell
    has enough plans to amortize them, so small screens stay serial and
    millisecond-fast while arbitrarily large what-if grids scale out).
    """
    from repro.configs import SHAPES, get_config
    from repro.core import autotune, collectives

    mesh = collectives.MeshSpec(axes=(("data", 16), ("model", 16)))
    rows = []
    by_cell: dict = {}
    for name in names:
        by_cell.setdefault(EXPERIMENTS[name]["cell"], []).append(name)

    for (arch, shape_name), exp_names in by_cell.items():
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        n = cfg.param_count()
        tokens = shape.global_batch * shape.seq_len
        plans = [autotune.PlanCandidate(name="baseline", mesh=mesh,
                                        tp_degree=16, microbatches=8,
                                        remat="full")]
        opt_bytes = [4.0 * n]
        for name in exp_names:
            ov = EXPERIMENTS[name]["override"]
            plans.append(autotune.PlanCandidate(
                name=name, mesh=mesh, tp_degree=16,
                microbatches=int(ov.get("microbatches", 8)),
                remat=ov.get("remat", "full")))
            # int8 block-quantized moments: ~2.05 B/param vs 4 B/param
            opt_bytes.append(2.05 * n if ov.get("moment_dtype") == "int8"
                             else 4.0 * n)

        costs = autotune.enumerate_plans(
            plans,
            model_flops=6.0 * n * tokens,
            param_bytes=2.0 * n,
            activation_bytes=2.0 * tokens * cfg.d_model
            * cfg.n_layers * 4,
            opt_state_bytes=opt_bytes,
            activation_peak_bytes=2.0 * tokens * cfg.d_model * 2,
            chunk_size=chunk_size, jobs=jobs)
        base = costs[0]
        print(f"=== screen: {arch} x {shape_name} "
              f"(baseline step {base.total_s:.3f}s) ===")
        for c in costs[1:]:
            ov = EXPERIMENTS[c.plan.name]["override"]
            opaque = " [+cfg_overrides not priced]" \
                if ov.get("cfg_overrides") else ""
            fits = "fits" if c.detail.get("feasible") else "OOM "
            print(f"  {c.plan.name:24s} [{fits}] step {c.total_s:7.3f}s "
                  f"({c.total_s / base.total_s:5.2f}x baseline){opaque}")
            rows.append({"experiment": c.plan.name, "arch": arch,
                         "shape": shape_name, "screen_step_s": c.total_s,
                         "baseline_step_s": base.total_s,
                         "feasible": bool(c.detail.get("feasible"))})
    if json_out:
        with open(json_out, "a") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
    return rows


def run(exp_name: str, json_out: str | None = None):
    # dryrun import must happen in a fresh process normally; here we are
    # the main module so set flags first
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.launch import dryrun

    exp = EXPERIMENTS[exp_name]
    arch, shape = exp["cell"]
    print(f"=== {exp_name}: {arch} x {shape} ===")
    print(f"hypothesis: {exp['hypothesis']}")
    art = dryrun.lower_cell(arch, shape, multi_pod=False,
                            plan_override=json.loads(
                                json.dumps(exp["override"])))
    rep = art["report"]
    mem = art["memory_analysis"]
    row = {
        "experiment": exp_name, "arch": arch, "shape": shape,
        "compute_term_s": rep.compute_term,
        "memory_term_s": rep.memory_term,
        "collective_term_s": rep.collective_term,
        "dominant": rep.dominant,
        "useful": rep.useful_flops_ratio,
        "roofline_fraction": rep.roofline_fraction,
        "hlo_flops": rep.hlo_flops,
        "hlo_bytes": rep.hlo_bytes,
        "collective_bytes": rep.collective_bytes,
        "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "compile_s": art["compile_seconds"],
    }
    print(json.dumps(row, indent=1))
    if json_out:
        with open(json_out, "a") as f:
            f.write(json.dumps(row) + "\n")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True,
                    help="experiment name or 'all' or comma list")
    ap.add_argument("--json", default=None)
    ap.add_argument("--screen", action="store_true",
                    help="napkin-price the plans via the batched engine "
                         "instead of lowering (fast pre-screen)")
    ap.add_argument("--jobs", type=int, default=0,
                    help="screen worker processes (0 = auto from "
                         "os.cpu_count(); pools engage only when a cell "
                         "has enough plans to amortize them)")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="plans per columnar pricing block "
                         "(0 = whole candidate list)")
    args = ap.parse_args()
    names = list(EXPERIMENTS) if args.exp == "all" else args.exp.split(",")
    if args.screen:
        screen(names, args.json, jobs=args.jobs,
               chunk_size=args.chunk_size or None)
        return
    for n in names:
        run(n, args.json)


if __name__ == "__main__":
    main()
